//! A panel of right-hand sides: `k` [`DistVector`]s sharing one layout.
//!
//! The multi-RHS paths (`ptrsm`, `plu_solve_panel`, block Krylov, the
//! `serve` scheduler) carry their columns through shared broadcast /
//! tile-sweep / reduction structure, but each column's *arithmetic* is
//! exactly the single-vector kernels' — batching changes cost accounting,
//! never values (the bit-identity contract `tests/multi_rhs.rs` pins).
//! Keeping the columns as plain [`DistVector`]s makes that contract true
//! by construction: any column can be handed to a single-RHS routine.

use super::{Descriptor, DistVector};
use crate::Scalar;

/// `k` conformable distributed vectors (an `n x k` RHS panel).
#[derive(Clone, Debug)]
pub struct DistMultiVector<S> {
    cols: Vec<DistVector<S>>,
}

impl<S: Scalar> DistMultiVector<S> {
    /// Bundle existing columns; all descriptors must match.
    pub fn from_cols(cols: Vec<DistVector<S>>) -> Self {
        assert!(!cols.is_empty(), "a multivector needs at least one column");
        let d = *cols[0].desc();
        for c in &cols {
            assert_eq!(c.desc(), &d, "multivector column descriptors differ");
        }
        DistMultiVector { cols }
    }

    /// `k` zero columns in the standard layout.
    pub fn zeros(desc: Descriptor, prow: usize, pcol: usize, k: usize) -> Self {
        Self::from_cols((0..k).map(|_| DistVector::zeros(desc, prow, pcol)).collect())
    }

    /// `k` columns, element `(i, j)` from `f`.
    pub fn from_fn(
        desc: Descriptor,
        prow: usize,
        pcol: usize,
        k: usize,
        f: impl Fn(usize, usize) -> S,
    ) -> Self {
        Self::from_cols(
            (0..k).map(|j| DistVector::from_fn(desc, prow, pcol, |i| f(i, j))).collect(),
        )
    }

    /// Number of columns.
    pub fn ncols(&self) -> usize {
        self.cols.len()
    }

    /// Shared layout descriptor.
    pub fn desc(&self) -> &Descriptor {
        self.cols[0].desc()
    }

    /// Column `j`.
    pub fn col(&self, j: usize) -> &DistVector<S> {
        &self.cols[j]
    }

    /// Column `j`, mutably.
    pub fn col_mut(&mut self, j: usize) -> &mut DistVector<S> {
        &mut self.cols[j]
    }

    /// All columns.
    pub fn cols(&self) -> &[DistVector<S>] {
        &self.cols
    }

    /// All columns, mutably (disjoint borrows per column).
    pub fn cols_mut(&mut self) -> &mut [DistVector<S>] {
        &mut self.cols
    }

    /// Deep copy (column-wise [`DistVector::clone_vec`]).
    pub fn clone_panel(&self) -> Self {
        DistMultiVector { cols: self.cols.iter().map(|c| c.clone_vec()).collect() }
    }

    /// Unbundle into the column vectors.
    pub fn into_cols(self) -> Vec<DistVector<S>> {
        self.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::MeshShape;

    #[test]
    fn construction_and_access() {
        let desc = Descriptor::new(10, 10, 4, MeshShape::new(1, 1));
        let mut m = DistMultiVector::<f64>::from_fn(desc, 0, 0, 3, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.ncols(), 3);
        assert_eq!(m.col(2).global_block(0)[1], 12.0);
        m.col_mut(0).global_block_mut(0)[0] = -1.0;
        let c = m.clone_panel();
        assert_eq!(c.col(0).global_block(0)[0], -1.0);
        assert_eq!(c.into_cols().len(), 3);
        let z = DistMultiVector::<f64>::zeros(desc, 0, 0, 2);
        assert_eq!(z.ncols(), 2);
        assert!(z.col(1).global_block(1).iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "descriptors differ")]
    fn mismatched_columns_panic() {
        let d1 = Descriptor::new(10, 10, 4, MeshShape::new(1, 1));
        let d2 = Descriptor::new(12, 12, 4, MeshShape::new(1, 1));
        DistMultiVector::from_cols(vec![
            DistVector::<f64>::zeros(d1, 0, 0),
            DistVector::<f64>::zeros(d2, 0, 0),
        ]);
    }
}
