//! Redistribution between the block-cyclic layout and host-side buffers,
//! plus the transpose redistribution — all built on real messages through
//! [`crate::comm`] so the virtual clock charges every byte moved.
//!
//! * [`gather_matrix`] / [`gather_vector`] — collect a distributed operand
//!   on world rank 0 (trimmed of padding); the verification path of every
//!   solver test and of [`crate::cluster::Cluster::solve`].
//! * [`scatter_matrix`] / [`scatter_vector`] — the inverse: rank 0 holds a
//!   host buffer and deals each rank its shard (identity/zero padded).
//! * [`ptranspose`] — the row↔column redistribution `B = A^T`: every tile
//!   `(ti, tj)` moves to the owner of `(tj, ti)` transposed, the step that
//!   turns a Cholesky `L` into the `L^T` the backward substitution reads.
//!
//! All five preserve the layout invariants documented in
//! [`super::matrix`] / [`super::vector`]: scatter re-applies the identity
//! (matrix) / zero (vector) padding, so a scatter is indistinguishable
//! from building the same operand with `from_fn`; gather reads only
//! process column 0's vector replicas (replication makes the others
//! redundant by invariant); ptranspose keeps identity padding intact
//! because the pad pattern is itself symmetric.

use super::descriptor::Descriptor;
use super::matrix::DistMatrix;
use super::vector::DistVector;
use crate::comm::{Payload, Tag};
use crate::mesh::Mesh;
use crate::Scalar;

/// Tag blocks owned by the redistribution routines (collectives translate
/// them into their own [`Tag`] variants, so they cannot cross-match the
/// solver tag ranges).
mod tags {
    pub const GATHER_MAT: u32 = 6_000;
    pub const GATHER_VEC: u32 = 6_001;
    pub const SCATTER_MAT: u32 = 6_002;
    pub const SCATTER_VEC: u32 = 6_003;
    /// Base of the per-tile p2p tag range used by `ptranspose`.
    pub const TRANSPOSE: u32 = 7_000;
}

/// This rank's tiles as one flat stream (local tile-major order).
fn tile_stream<S: Scalar>(a: &DistMatrix<S>) -> Vec<S> {
    let t2 = a.desc().tile * a.desc().tile;
    let mut out = Vec::with_capacity(a.local_mt() * a.local_nt() * t2);
    for lti in 0..a.local_mt() {
        for ltj in 0..a.local_nt() {
            out.extend_from_slice(a.tile(lti, ltj));
        }
    }
    out
}

/// Gather a distributed matrix to world rank 0 as a row-major `m x n`
/// buffer (padding trimmed).  Returns `Some` on rank 0, `None` elsewhere.
/// Every rank must call (it is a collective).
pub fn gather_matrix<S: Scalar>(mesh: &Mesh<'_, S>, a: &DistMatrix<S>) -> Option<Vec<S>> {
    let desc = *a.desc();
    let t = desc.tile;
    let streams = mesh.world().gather(0, tags::GATHER_MAT, tile_stream(a))?;
    let mut out = vec![S::zero(); desc.m * desc.n];
    for (rank, data) in streams.iter().enumerate() {
        let (pr, pc) = mesh.shape().coords(rank);
        let lnt = desc.local_nt(pc);
        for lti in 0..desc.local_mt(pr) {
            let ti = desc.global_ti(pr, lti);
            for ltj in 0..lnt {
                let tj = desc.global_tj(pc, ltj);
                let tile = &data[(lti * lnt + ltj) * t * t..][..t * t];
                for r in 0..t {
                    let gi = ti * t + r;
                    if gi >= desc.m {
                        break;
                    }
                    for (c, &v) in tile[r * t..(r + 1) * t].iter().enumerate() {
                        let gj = tj * t + c;
                        if gj < desc.n {
                            out[gi * desc.n + gj] = v;
                        }
                    }
                }
            }
        }
    }
    Some(out)
}

/// Gather a distributed vector to world rank 0 as a length-`m` buffer
/// (padding trimmed).  Replicas are identical, so only process column 0's
/// blocks are read.  Collective: every rank must call.
pub fn gather_vector<S: Scalar>(mesh: &Mesh<'_, S>, v: &DistVector<S>) -> Option<Vec<S>> {
    let desc = *v.desc();
    let t = desc.tile;
    let mut mine = Vec::with_capacity(v.local_blocks() * t);
    for l in 0..v.local_blocks() {
        mine.extend_from_slice(v.block(l));
    }
    let streams = mesh.world().gather(0, tags::GATHER_VEC, mine)?;
    let mut out = vec![S::zero(); desc.m];
    for (rank, data) in streams.iter().enumerate() {
        let (pr, pc) = mesh.shape().coords(rank);
        if pc != 0 {
            continue; // replicas: column 0 suffices
        }
        for l in 0..desc.local_mt(pr) {
            let ti = desc.global_ti(pr, l);
            for (k, &x) in data[l * t..(l + 1) * t].iter().enumerate() {
                let gi = ti * t + k;
                if gi < desc.m {
                    out[gi] = x;
                }
            }
        }
    }
    Some(out)
}

/// Scatter a host row-major `m x n` buffer (present on world rank 0) into
/// the block-cyclic layout.  Edge tiles take the identity padding, so the
/// result is exactly what [`DistMatrix::from_fn`] over the same elements
/// would build.  Collective: every rank must call; only rank 0's
/// `host` is read.
pub fn scatter_matrix<S: Scalar>(
    mesh: &Mesh<'_, S>,
    desc: Descriptor,
    host: Option<&[S]>,
) -> DistMatrix<S> {
    let world = mesh.world();
    let t = desc.tile;
    let per_rank = if world.rank() == 0 {
        let host = host.expect("scatter_matrix: rank 0 must supply the host matrix");
        assert_eq!(host.len(), desc.m * desc.n, "host buffer is not m x n");
        let mut blocks = Vec::with_capacity(world.size());
        for rank in 0..world.size() {
            let (pr, pc) = mesh.shape().coords(rank);
            let (lmt, lnt) = (desc.local_mt(pr), desc.local_nt(pc));
            let mut data = Vec::with_capacity(lmt * lnt * t * t);
            for lti in 0..lmt {
                let ti = desc.global_ti(pr, lti);
                for ltj in 0..lnt {
                    let tj = desc.global_tj(pc, ltj);
                    for r in 0..t {
                        let gi = ti * t + r;
                        for c in 0..t {
                            let gj = tj * t + c;
                            data.push(if gi < desc.m && gj < desc.n {
                                host[gi * desc.n + gj]
                            } else {
                                desc.pad(gi, gj)
                            });
                        }
                    }
                }
            }
            blocks.push(data);
        }
        Some(blocks)
    } else {
        None
    };
    let mine = world.scatter(0, tags::SCATTER_MAT, per_rank);
    DistMatrix::from_tiles(desc, mesh.row(), mesh.col(), mine)
}

/// Scatter a host length-`m` buffer (present on world rank 0) into the
/// row-distributed / column-replicated vector layout (zero padded).
/// Collective: every rank must call.
pub fn scatter_vector<S: Scalar>(
    mesh: &Mesh<'_, S>,
    desc: Descriptor,
    host: Option<&[S]>,
) -> DistVector<S> {
    let world = mesh.world();
    let t = desc.tile;
    let per_rank = if world.rank() == 0 {
        let host = host.expect("scatter_vector: rank 0 must supply the host vector");
        assert_eq!(host.len(), desc.m, "host buffer is not length m");
        let mut blocks = Vec::with_capacity(world.size());
        for rank in 0..world.size() {
            let (pr, _pc) = mesh.shape().coords(rank);
            let lmt = desc.local_mt(pr);
            let mut data = Vec::with_capacity(lmt * t);
            for l in 0..lmt {
                let ti = desc.global_ti(pr, l);
                for k in 0..t {
                    let gi = ti * t + k;
                    data.push(if gi < desc.m { host[gi] } else { S::zero() });
                }
            }
            blocks.push(data);
        }
        Some(blocks)
    } else {
        None
    };
    let mine = world.scatter(0, tags::SCATTER_VEC, per_rank);
    DistVector::from_blocks(desc, mesh.row(), mesh.col(), mine)
}

/// Transpose redistribution: returns `B = A^T` in the same descriptor.
/// Tile `(ti, tj)` transposes locally and moves to the owner of `(tj, ti)`;
/// with the buffered transport every rank can post all its sends before
/// draining its receives, so the exchange is deadlock-free in one round.
pub fn ptranspose<S: Scalar>(mesh: &Mesh<'_, S>, a: &DistMatrix<S>) -> DistMatrix<S> {
    let desc = *a.desc();
    assert!(desc.is_square(), "ptranspose requires a square matrix");
    let t = desc.tile;
    let nt = desc.nt();
    let comm = mesh.comm();
    // Tag keyed by the *destination* tile coordinates in B.
    let tag = |ti: usize, tj: usize| Tag::P2p(tags::TRANSPOSE + (ti * nt + tj) as u32);

    let mut b = DistMatrix::zeros(desc, mesh.row(), mesh.col());

    // Send phase (self-destined tiles are placed directly).
    let mut local: Vec<(usize, usize, Vec<S>)> = Vec::new();
    for (lti, ltj, ti, tj) in a.owned_tiles() {
        let src = a.tile(lti, ltj);
        let mut tt = vec![S::zero(); t * t];
        for r in 0..t {
            for c in 0..t {
                tt[c * t + r] = src[r * t + c];
            }
        }
        let (dr, dc) = desc.owner(tj, ti);
        let dst = desc.shape.rank_at(dr, dc);
        if dst == comm.rank() {
            local.push((tj, ti, tt));
        } else {
            comm.send(dst, tag(tj, ti), Payload::Data(tt));
        }
    }
    for (ti, tj, tt) in local {
        b.global_tile_mut(ti, tj).copy_from_slice(&tt);
    }

    // Receive phase: fill every remotely-sourced tile this rank owns in B.
    let coords: Vec<_> = b.owned_tiles().collect();
    for (lti, ltj, ti, tj) in coords {
        let (sr, sc) = desc.owner(tj, ti); // B(ti,tj) comes from A(tj,ti)
        let src = desc.shape.rank_at(sr, sc);
        if src != comm.rank() {
            let data = comm.recv(src, tag(ti, tj)).into_data();
            b.tile_mut(lti, ltj).copy_from_slice(&data);
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{NetworkModel, World};
    use crate::mesh::MeshShape;

    fn elem(i: usize, j: usize) -> f64 {
        (i * 57 + j * 13 + 1) as f64
    }

    #[test]
    fn scatter_gather_matrix_roundtrip() {
        for (m, n, tile, pr, pc) in [(12, 12, 4, 2, 2), (13, 9, 4, 2, 3), (7, 7, 3, 1, 2)] {
            let host: Vec<f64> = (0..m * n).map(|k| elem(k / n, k % n)).collect();
            let host2 = host.clone();
            let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let desc = Descriptor::new(m, n, tile, mesh.shape());
                let root = if comm.rank() == 0 { Some(&host2[..]) } else { None };
                let a = scatter_matrix(&mesh, desc, root);
                gather_matrix(&mesh, &a)
            });
            assert_eq!(out[0].as_ref().unwrap(), &host, "{m}x{n}/{tile} on {pr}x{pc}");
        }
    }

    #[test]
    fn scatter_matches_from_fn_including_padding() {
        let (m, tile, pr, pc) = (10usize, 4usize, 2usize, 2usize);
        let host: Vec<f64> = (0..m * m).map(|k| elem(k / m, k % m)).collect();
        let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let desc = Descriptor::new(m, m, tile, mesh.shape());
            let root = if comm.rank() == 0 { Some(&host[..]) } else { None };
            let scattered = scatter_matrix(&mesh, desc, root);
            let direct = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), elem);
            let mut same = true;
            for (lti, ltj, _, _) in scattered.owned_tiles() {
                same &= scattered.tile(lti, ltj) == direct.tile(lti, ltj);
            }
            same
        });
        assert!(out.into_iter().all(|ok| ok), "scatter must equal from_fn, pad included");
    }

    #[test]
    fn scatter_gather_vector_roundtrip() {
        for (m, tile, pr, pc) in [(16, 4, 2, 2), (11, 3, 3, 1), (5, 4, 1, 3)] {
            let host: Vec<f64> = (0..m).map(|i| (i * i) as f64).collect();
            let host2 = host.clone();
            let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let desc = Descriptor::new(m, m, tile, mesh.shape());
                let root = if comm.rank() == 0 { Some(&host2[..]) } else { None };
                let v = scatter_vector(&mesh, desc, root);
                gather_vector(&mesh, &v)
            });
            assert_eq!(out[0].as_ref().unwrap(), &host, "m={m} tile={tile} {pr}x{pc}");
        }
    }

    #[test]
    fn transpose_matches_host_transpose() {
        for (n, tile, pr, pc) in [(12, 4, 2, 2), (10, 4, 2, 3), (9, 3, 1, 1)] {
            let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let desc = Descriptor::new(n, n, tile, mesh.shape());
                let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), elem);
                let at = ptranspose(&mesh, &a);
                gather_matrix(&mesh, &at)
            });
            let got = out[0].as_ref().unwrap();
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(got[i * n + j], elem(j, i), "n={n} {pr}x{pc} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn transpose_charges_comm_time_on_multirank_meshes() {
        let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
            let desc = Descriptor::new(16, 16, 4, mesh.shape());
            let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), elem);
            let _ = ptranspose(&mesh, &a);
            comm.clock().now()
        });
        assert!(
            out.iter().any(|&t| t > 0.0),
            "cross-rank tile moves must advance the virtual clock: {out:?}"
        );
    }
}
