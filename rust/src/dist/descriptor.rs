//! The block-cyclic distribution descriptor — the single source of truth for
//! "which rank owns which tile, and where does it live locally".
//!
//! A global `m x n` matrix is cut into `TILE x TILE` tiles (the last tile row
//! and column are padded; see [`crate::dist::matrix`]).  Tile `(ti, tj)` is
//! assigned to the process at mesh coordinates `(ti mod pr, tj mod pc)` —
//! the classic 2-D block-cyclic map (ScaLAPACK / CUPLSS), which keeps every
//! phase of a right-looking factorisation load-balanced as the active window
//! shrinks.  Locally a rank stores its tiles densely: global tile row `ti`
//! sits at local row `ti / pr`, so global↔local index conversion is pure
//! arithmetic — no lookup tables, no communication.
//!
//! The owner and index maps are total and mutually inverse — for every
//! tile, `global_ti(owner_row, local_ti(ti)) == ti` (and likewise for
//! columns):
//!
//! ```
//! use cuplss::dist::Descriptor;
//! use cuplss::mesh::MeshShape;
//!
//! // 13x13 in 4-wide tiles on a 2x3 mesh: 4x4 tiles, last one padded.
//! let d = Descriptor::new(13, 13, 4, MeshShape::new(2, 3));
//! assert_eq!((d.mt(), d.nt()), (4, 4));
//! // Tile (2, 3): owned by mesh rank (2 mod 2, 3 mod 3) = (0, 0) ...
//! assert_eq!(d.owner(2, 3), (0, 0));
//! // ... stored locally at (2 / 2, 3 / 3) = (1, 1) ...
//! assert_eq!((d.local_ti(2), d.local_tj(3)), (1, 1));
//! // ... and the maps invert exactly.
//! assert_eq!(d.global_ti(0, d.local_ti(2)), 2);
//! assert_eq!(d.global_tj(0, d.local_tj(3)), 3);
//! ```
//!
//! Per-rank tile counts partition the grid, and positions beyond the real
//! extent take the *identity* padding (pad diagonal 1, off-diagonal 0 —
//! the invariant that lets padded factorisations embed real ones exactly
//! while padded matvec terms vanish against zero-padded vectors):
//!
//! ```
//! use cuplss::dist::Descriptor;
//! use cuplss::mesh::MeshShape;
//!
//! // 10 rows in 4-wide tiles over 2 process rows: 3 tile rows, 2 padded.
//! let d = Descriptor::new(10, 10, 4, MeshShape::new(2, 2));
//! assert_eq!(d.mt(), 3);
//! assert_eq!(d.local_mt(0), 2); // process row 0 holds tile rows {0, 2}
//! assert_eq!(d.local_mt(1), 1); // process row 1 holds tile row {1}
//! assert_eq!(d.local_mt(0) + d.local_mt(1), d.mt());
//! assert_eq!(d.padded_m(), 12);
//! assert_eq!(d.pad::<f64>(11, 11), 1.0); // pad diagonal: identity
//! assert_eq!(d.pad::<f64>(11, 3), 0.0); // pad off-diagonal: zero
//! ```

use crate::mesh::MeshShape;

/// Integer ceiling division (`ceil(a / b)`).
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Shape + layout descriptor of one distributed matrix (or the row layout of
/// a distributed vector).  `Copy`, compared by value: two operands are
/// conformable exactly when their descriptors are equal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockDesc {
    /// Global rows.
    pub m: usize,
    /// Global columns.
    pub n: usize,
    /// Tile edge (every local tile op is `tile x tile`).
    pub tile: usize,
    /// The process-grid extents this matrix is distributed over.
    pub shape: MeshShape,
}

/// The name the rest of the crate uses for [`BlockDesc`].
pub type Descriptor = BlockDesc;

impl BlockDesc {
    /// Describe an `m x n` matrix in `tile`-sized tiles over `shape`.
    pub fn new(m: usize, n: usize, tile: usize, shape: MeshShape) -> Self {
        assert!(m > 0 && n > 0, "empty matrix {m}x{n}");
        assert!(tile > 0, "tile size must be positive");
        BlockDesc { m, n, tile, shape }
    }

    /// Is the global shape square?
    pub fn is_square(&self) -> bool {
        self.m == self.n
    }

    /// Tile rows (`ceil(m / tile)`).
    pub fn mt(&self) -> usize {
        ceil_div(self.m, self.tile)
    }

    /// Tile columns (`ceil(n / tile)`).
    pub fn nt(&self) -> usize {
        ceil_div(self.n, self.tile)
    }

    /// Mesh coordinates of the rank owning tile `(ti, tj)`.
    pub fn owner(&self, ti: usize, tj: usize) -> (usize, usize) {
        (ti % self.shape.pr, tj % self.shape.pc)
    }

    /// Local tile-row index of global tile row `ti` on its owning process
    /// row.
    pub fn local_ti(&self, ti: usize) -> usize {
        ti / self.shape.pr
    }

    /// Local tile-column index of global tile column `tj` on its owning
    /// process column.
    pub fn local_tj(&self, tj: usize) -> usize {
        tj / self.shape.pc
    }

    /// Global tile row stored at local row `lti` on process row `prow`.
    pub fn global_ti(&self, prow: usize, lti: usize) -> usize {
        lti * self.shape.pr + prow
    }

    /// Global tile column stored at local column `ltj` on process column
    /// `pcol`.
    pub fn global_tj(&self, pcol: usize, ltj: usize) -> usize {
        ltj * self.shape.pc + pcol
    }

    /// Number of tile rows owned by process row `prow`
    /// (`|{ti < mt : ti ≡ prow (mod pr)}|`).
    pub fn local_mt(&self, prow: usize) -> usize {
        let (mt, pr) = (self.mt(), self.shape.pr);
        debug_assert!(prow < pr, "process row {prow} outside mesh with {pr} rows");
        (mt + pr - 1 - prow) / pr
    }

    /// Number of tile columns owned by process column `pcol`.
    pub fn local_nt(&self, pcol: usize) -> usize {
        let (nt, pc) = (self.nt(), self.shape.pc);
        debug_assert!(pcol < pc, "process column {pcol} outside mesh with {pc} columns");
        (nt + pc - 1 - pcol) / pc
    }

    /// Padded global extent of the tile-row range (`mt * tile >= m`).
    pub fn padded_m(&self) -> usize {
        self.mt() * self.tile
    }

    /// Padded global extent of the tile-column range.
    pub fn padded_n(&self) -> usize {
        self.nt() * self.tile
    }

    /// The value stored at padded position `(gi, gj)` when it falls outside
    /// the real matrix: identity padding.  Pad rows/columns carry `e_i` so a
    /// padded LU/Cholesky factorisation embeds the original factorisation
    /// exactly, and padded matvec/dot contributions vanish against the
    /// zero-padded vector blocks.
    pub fn pad<S: crate::Scalar>(&self, gi: usize, gj: usize) -> S {
        debug_assert!(gi >= self.m || gj >= self.n);
        if gi == gj {
            S::one()
        } else {
            S::zero()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn desc(m: usize, n: usize, tile: usize, pr: usize, pc: usize) -> BlockDesc {
        BlockDesc::new(m, n, tile, MeshShape::new(pr, pc))
    }

    #[test]
    fn tile_counts_round_up() {
        let d = desc(13, 7, 4, 2, 3);
        assert_eq!(d.mt(), 4);
        assert_eq!(d.nt(), 2);
        assert_eq!(d.padded_m(), 16);
        assert_eq!(d.padded_n(), 8);
        assert!(!d.is_square());
    }

    #[test]
    fn global_local_owner_roundtrip_non_divisible() {
        // Non-divisible everything: 5 tile rows over 3 process rows,
        // 7 tile cols over 2 process cols.
        let d = desc(5 * 3 - 1, 7 * 2 - 1, 3, 3, 2);
        for ti in 0..d.mt() {
            for tj in 0..d.nt() {
                let (r, c) = d.owner(ti, tj);
                assert!(r < 3 && c < 2);
                assert_eq!(d.global_ti(r, d.local_ti(ti)), ti);
                assert_eq!(d.global_tj(c, d.local_tj(tj)), tj);
            }
        }
    }

    #[test]
    fn local_counts_partition_the_grid() {
        for (m, n, tile, pr, pc) in
            [(1, 1, 1, 4, 4), (17, 11, 3, 2, 3), (64, 64, 8, 3, 5), (9, 30, 4, 4, 1)]
        {
            let d = desc(m, n, tile, pr, pc);
            let rows: usize = (0..pr).map(|r| d.local_mt(r)).sum();
            let cols: usize = (0..pc).map(|c| d.local_nt(c)).sum();
            assert_eq!(rows, d.mt(), "{m}x{n}/{tile} on {pr}x{pc}");
            assert_eq!(cols, d.nt());
            // And each count matches a direct enumeration.
            for r in 0..pr {
                let direct = (0..d.mt()).filter(|ti| ti % pr == r).count();
                assert_eq!(d.local_mt(r), direct);
            }
            for c in 0..pc {
                let direct = (0..d.nt()).filter(|tj| tj % pc == c).count();
                assert_eq!(d.local_nt(c), direct);
            }
        }
    }

    #[test]
    fn ranks_without_tiles_have_zero_count() {
        // 1 tile row over 4 process rows: rows 1..3 own nothing.
        let d = desc(3, 3, 4, 4, 4);
        assert_eq!(d.mt(), 1);
        assert_eq!(d.local_mt(0), 1);
        for r in 1..4 {
            assert_eq!(d.local_mt(r), 0);
        }
    }

    #[test]
    fn descriptors_compare_by_value() {
        let a = desc(8, 8, 4, 2, 2);
        let b = desc(8, 8, 4, 2, 2);
        let c = desc(8, 8, 2, 2, 2);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn identity_padding_values() {
        let d = desc(5, 5, 4, 1, 1);
        assert_eq!(d.pad::<f64>(6, 6), 1.0);
        assert_eq!(d.pad::<f64>(6, 5), 0.0);
        assert_eq!(d.pad::<f64>(2, 7), 0.0);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }
}
