//! The distributed vector: row-distributed, column-replicated.
//!
//! A length-`m` vector is cut into the same `tile`-sized blocks as the
//! matrix's tile rows; block `ti` lives on process row `ti mod pr` and is
//! **replicated on every process column** of that row.  This is the layout
//! every solver in the crate assumes, and its invariants are what make the
//! Krylov recurrences communication-minimal:
//!
//! * **replication rule** — all `pc` replicas of a block are bit-identical
//!   at every step: BLAS-1 ops apply the same local update everywhere, and
//!   collective results (allreduced dots, matvec outputs) are identical by
//!   construction, so no re-synchronisation ever happens;
//! * **zero padding** — block entries at or beyond `m` are exactly zero,
//!   so padded dot/matvec terms vanish against the matrix's identity
//!   padding; every writer of a vector that feeds dots or matvecs must
//!   keep them zero.  (One documented exception: the Jacobi
//!   preconditioner's *scale* vector stores 1s at padded positions — it
//!   multiplies operands elementwise instead of entering reductions, and
//!   pad scales of 1 are what preserve the matrix identity padding; see
//!   [`crate::solvers::JacobiPrecond`].);
//! * **conformability is descriptor equality** — a vector pairs with a
//!   matrix (dense [`crate::dist::DistMatrix`] or sparse
//!   [`crate::sparse::DistCsrMatrix`]) iff the descriptors compare equal;
//! * a distributed dot needs one *column*-comm allreduce (the column's
//!   members, one per process row, jointly hold the whole vector), and
//!   `pgemv`/`pspmv` consume and produce this same layout, so solver
//!   iterations chain without redistribution.

use super::descriptor::Descriptor;
use crate::Scalar;

/// One rank's replica of a row-distributed, column-replicated vector.
#[derive(Clone, Debug)]
pub struct DistVector<S: Scalar> {
    desc: Descriptor,
    prow: usize,
    pcol: usize,
    /// `desc.local_mt(prow)` blocks of `desc.tile` elements.
    blocks: Vec<Vec<S>>,
}

impl<S: Scalar> DistVector<S> {
    /// The all-zero vector for the rank at `(prow, pcol)`.
    pub fn zeros(desc: Descriptor, prow: usize, pcol: usize) -> Self {
        assert!(
            prow < desc.shape.pr && pcol < desc.shape.pc,
            "coords ({prow},{pcol}) outside mesh {}x{}",
            desc.shape.pr,
            desc.shape.pc
        );
        let blocks = (0..desc.local_mt(prow)).map(|_| vec![S::zero(); desc.tile]).collect();
        DistVector { desc, prow, pcol, blocks }
    }

    /// Build this rank's blocks from a global element function `f(i)`;
    /// positions at or beyond `desc.m` are zero padded.
    pub fn from_fn(desc: Descriptor, prow: usize, pcol: usize, f: impl Fn(usize) -> S) -> Self {
        let mut v = Self::zeros(desc, prow, pcol);
        let t = desc.tile;
        for (l, block) in v.blocks.iter_mut().enumerate() {
            let ti = desc.global_ti(prow, l);
            for (k, slot) in block.iter_mut().enumerate() {
                let gi = ti * t + k;
                *slot = if gi < desc.m { f(gi) } else { S::zero() };
            }
        }
        v
    }

    /// Rebuild from a flat block stream (ascending local block order, as
    /// produced by the scatter redistribution).
    pub(crate) fn from_blocks(
        desc: Descriptor,
        prow: usize,
        pcol: usize,
        data: Vec<S>,
    ) -> Self {
        let mut v = Self::zeros(desc, prow, pcol);
        assert_eq!(data.len(), v.blocks.len() * desc.tile, "block stream length mismatch");
        for (l, block) in v.blocks.iter_mut().enumerate() {
            block.copy_from_slice(&data[l * desc.tile..(l + 1) * desc.tile]);
        }
        v
    }

    /// The layout descriptor (shared with the matrices it pairs with).
    pub fn desc(&self) -> &Descriptor {
        &self.desc
    }

    /// This rank's process row.
    pub fn prow(&self) -> usize {
        self.prow
    }

    /// This rank's process column.
    pub fn pcol(&self) -> usize {
        self.pcol
    }

    /// Number of blocks stored locally.
    pub fn local_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Does this rank's process row own global block `ti`?
    pub fn owns(&self, ti: usize) -> bool {
        ti % self.desc.shape.pr == self.prow
    }

    /// Local block `l` (length `tile`).
    pub fn block(&self, l: usize) -> &[S] {
        &self.blocks[l]
    }

    /// Mutable local block `l`.
    pub fn block_mut(&mut self, l: usize) -> &mut [S] {
        &mut self.blocks[l]
    }

    /// Block addressed by *global* tile index; this process row must own it.
    pub fn global_block(&self, ti: usize) -> &[S] {
        debug_assert!(self.owns(ti), "block {ti} not on process row {}", self.prow);
        &self.blocks[self.desc.local_ti(ti)]
    }

    /// Mutable block addressed by global tile index (returned as the owned
    /// buffer so callers can `clone()` it straight into a payload).
    pub fn global_block_mut(&mut self, ti: usize) -> &mut Vec<S> {
        debug_assert!(self.owns(ti), "block {ti} not on process row {}", self.prow);
        let l = self.desc.local_ti(ti);
        &mut self.blocks[l]
    }

    /// An owned copy with the same layout (the solvers' working-vector
    /// constructor).
    pub fn clone_vec(&self) -> Self {
        self.clone()
    }

    /// Overwrite this replica with `other` (layouts must match).
    pub fn copy_from(&mut self, other: &Self) {
        assert_eq!(&self.desc, other.desc(), "copy_from layout mismatch");
        debug_assert_eq!((self.prow, self.pcol), (other.prow, other.pcol));
        for (dst, src) in self.blocks.iter_mut().zip(&other.blocks) {
            dst.copy_from_slice(src);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::MeshShape;

    fn desc(m: usize, tile: usize, pr: usize, pc: usize) -> Descriptor {
        Descriptor::new(m, m, tile, MeshShape::new(pr, pc))
    }

    #[test]
    fn rows_partition_and_columns_replicate() {
        let d = desc(11, 4, 2, 3);
        let mut owners = vec![0u32; d.m];
        for r in 0..2 {
            let replicas: Vec<DistVector<f64>> =
                (0..3).map(|c| DistVector::from_fn(d, r, c, |i| i as f64)).collect();
            for l in 0..replicas[0].local_blocks() {
                let ti = d.global_ti(r, l);
                for v in &replicas {
                    assert_eq!(v.block(l), replicas[0].block(l), "replica divergence");
                }
                for k in 0..d.tile {
                    let gi = ti * d.tile + k;
                    if gi < d.m {
                        assert_eq!(replicas[0].block(l)[k], gi as f64);
                        owners[gi] += 1;
                    } else {
                        assert_eq!(replicas[0].block(l)[k], 0.0, "pad must be zero");
                    }
                }
            }
        }
        assert!(owners.iter().all(|&k| k == 1));
    }

    #[test]
    fn global_block_addressing() {
        let d = desc(16, 4, 2, 1);
        let mut v = DistVector::from_fn(d, 1, 0, |i| i as f32);
        // process row 1 owns blocks 1 and 3
        assert!(v.owns(1) && v.owns(3) && !v.owns(2));
        assert_eq!(v.global_block(3)[0], 12.0);
        v.global_block_mut(3)[0] = -5.0;
        assert_eq!(v.block(1)[0], -5.0);
    }

    #[test]
    fn clone_and_copy_roundtrip() {
        let d = desc(9, 4, 1, 1);
        let v = DistVector::from_fn(d, 0, 0, |i| (i * i) as f64);
        let mut w = DistVector::zeros(d, 0, 0);
        w.copy_from(&v);
        let u = v.clone_vec();
        for l in 0..v.local_blocks() {
            assert_eq!(w.block(l), v.block(l));
            assert_eq!(u.block(l), v.block(l));
        }
    }
}
