//! The distributed dense matrix: one rank's shard of a 2-D block-cyclic
//! matrix.
//!
//! Storage is tile-major: `local_mt x local_nt` tiles, each a packed
//! row-major `tile x tile` buffer, so every local operand handed to the
//! [`crate::accel::Engine`] is one of a closed set of fixed-shape buffers
//! (the AOT-executable contract).
//!
//! Invariants every consumer may rely on:
//!
//! * **ownership** — the rank at mesh coordinates `(prow, pcol)` holds
//!   exactly the tiles `{(ti, tj) : ti ≡ prow (mod pr), tj ≡ pcol (mod
//!   pc)}` ([`super::descriptor::BlockDesc::owner`]); jointly the shards
//!   cover every global element exactly once;
//! * **identity padding of edge tiles** ([`super::descriptor::BlockDesc::pad`]):
//!   out-of-range diagonal entries are 1, off-diagonal 0, which embeds the
//!   real factorisation exactly inside the padded one (pad rows of L/U are
//!   `e_i`, never pivoted against) and keeps padded matvec contributions at
//!   zero against zero-padded vectors;
//! * **conformability is descriptor equality** — two operands interoperate
//!   iff their [`Descriptor`]s compare equal; every PBLAS routine asserts
//!   this before communicating.

use super::descriptor::Descriptor;
use crate::Scalar;

/// One rank's shard of a block-cyclic distributed matrix.
#[derive(Clone, Debug)]
pub struct DistMatrix<S: Scalar> {
    desc: Descriptor,
    prow: usize,
    pcol: usize,
    lmt: usize,
    lnt: usize,
    /// `lmt * lnt` tiles, row-major by (local tile row, local tile col).
    tiles: Vec<Vec<S>>,
}

impl<S: Scalar> DistMatrix<S> {
    /// The all-zero shard for the rank at mesh coordinates `(prow, pcol)`.
    pub fn zeros(desc: Descriptor, prow: usize, pcol: usize) -> Self {
        assert!(
            prow < desc.shape.pr && pcol < desc.shape.pc,
            "coords ({prow},{pcol}) outside mesh {}x{}",
            desc.shape.pr,
            desc.shape.pc
        );
        let lmt = desc.local_mt(prow);
        let lnt = desc.local_nt(pcol);
        let t2 = desc.tile * desc.tile;
        let tiles = (0..lmt * lnt).map(|_| vec![S::zero(); t2]).collect();
        DistMatrix { desc, prow, pcol, lmt, lnt, tiles }
    }

    /// Build this rank's shard from a global element function `f(i, j)`.
    /// Every rank evaluates only its own tiles (the paper's step 2: each
    /// node initialises its shard locally, no data movement).  Positions
    /// outside `m x n` take the identity padding.
    pub fn from_fn(
        desc: Descriptor,
        prow: usize,
        pcol: usize,
        f: impl Fn(usize, usize) -> S,
    ) -> Self {
        let mut a = Self::zeros(desc, prow, pcol);
        let t = desc.tile;
        for lti in 0..a.lmt {
            let ti = desc.global_ti(prow, lti);
            for ltj in 0..a.lnt {
                let tj = desc.global_tj(pcol, ltj);
                let tile = &mut a.tiles[lti * a.lnt + ltj];
                for r in 0..t {
                    let gi = ti * t + r;
                    for (c, slot) in tile[r * t..(r + 1) * t].iter_mut().enumerate() {
                        let gj = tj * t + c;
                        *slot = if gi < desc.m && gj < desc.n {
                            f(gi, gj)
                        } else {
                            desc.pad(gi, gj)
                        };
                    }
                }
            }
        }
        a
    }

    /// Rebuild a shard from a flat tile stream (local tile-major order, as
    /// produced by the gather/scatter redistributions).
    pub(crate) fn from_tiles(
        desc: Descriptor,
        prow: usize,
        pcol: usize,
        data: Vec<S>,
    ) -> Self {
        let mut a = Self::zeros(desc, prow, pcol);
        let t2 = desc.tile * desc.tile;
        assert_eq!(data.len(), a.lmt * a.lnt * t2, "tile stream length mismatch");
        for (l, tile) in a.tiles.iter_mut().enumerate() {
            tile.copy_from_slice(&data[l * t2..(l + 1) * t2]);
        }
        a
    }

    /// The layout descriptor.
    pub fn desc(&self) -> &Descriptor {
        &self.desc
    }

    /// This rank's process row.
    pub fn prow(&self) -> usize {
        self.prow
    }

    /// This rank's process column.
    pub fn pcol(&self) -> usize {
        self.pcol
    }

    /// Local tile rows on this rank.
    pub fn local_mt(&self) -> usize {
        self.lmt
    }

    /// Local tile columns on this rank.
    pub fn local_nt(&self) -> usize {
        self.lnt
    }

    /// Does this rank's process row own global tile row `ti`?
    pub fn owns_tile_row(&self, ti: usize) -> bool {
        ti % self.desc.shape.pr == self.prow
    }

    /// Does this rank's process column own global tile column `tj`?
    pub fn owns_tile_col(&self, tj: usize) -> bool {
        tj % self.desc.shape.pc == self.pcol
    }

    /// Local tile at `(lti, ltj)` (packed row-major `tile x tile`).
    pub fn tile(&self, lti: usize, ltj: usize) -> &[S] {
        &self.tiles[lti * self.lnt + ltj]
    }

    /// Mutable local tile at `(lti, ltj)`.
    pub fn tile_mut(&mut self, lti: usize, ltj: usize) -> &mut [S] {
        &mut self.tiles[lti * self.lnt + ltj]
    }

    /// Tile addressed by *global* tile coordinates; this rank must own it.
    pub fn global_tile(&self, ti: usize, tj: usize) -> &[S] {
        debug_assert_eq!(self.desc.owner(ti, tj), (self.prow, self.pcol));
        self.tile(self.desc.local_ti(ti), self.desc.local_tj(tj))
    }

    /// Mutable tile addressed by global tile coordinates (returned as the
    /// owned buffer so callers can `clone()` it straight into a payload).
    pub fn global_tile_mut(&mut self, ti: usize, tj: usize) -> &mut Vec<S> {
        debug_assert_eq!(self.desc.owner(ti, tj), (self.prow, self.pcol));
        let idx = self.desc.local_ti(ti) * self.lnt + self.desc.local_tj(tj);
        &mut self.tiles[idx]
    }

    /// Iterate this rank's tiles as `(lti, ltj, ti, tj)` — local indices
    /// paired with the global tile coordinates they hold.
    pub fn owned_tiles(&self) -> impl Iterator<Item = (usize, usize, usize, usize)> + '_ {
        let (desc, prow, pcol, lnt) = (self.desc, self.prow, self.pcol, self.lnt);
        (0..self.lmt).flat_map(move |lti| {
            (0..lnt).map(move |ltj| {
                (lti, ltj, desc.global_ti(prow, lti), desc.global_tj(pcol, ltj))
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::MeshShape;

    fn desc(m: usize, n: usize, tile: usize, pr: usize, pc: usize) -> Descriptor {
        Descriptor::new(m, n, tile, MeshShape::new(pr, pc))
    }

    #[test]
    fn shards_jointly_cover_every_element_once() {
        let d = desc(13, 9, 4, 2, 3);
        let mut seen = vec![0u32; d.m * d.n];
        for r in 0..2 {
            for c in 0..3 {
                let a = DistMatrix::from_fn(d, r, c, |i, j| (i * 100 + j) as f64);
                for (lti, ltj, ti, tj) in a.owned_tiles() {
                    let tile = a.tile(lti, ltj);
                    for rr in 0..d.tile {
                        for cc in 0..d.tile {
                            let (gi, gj) = (ti * d.tile + rr, tj * d.tile + cc);
                            if gi < d.m && gj < d.n {
                                assert_eq!(tile[rr * d.tile + cc], (gi * 100 + gj) as f64);
                                seen[gi * d.n + gj] += 1;
                            }
                        }
                    }
                }
            }
        }
        assert!(seen.iter().all(|&k| k == 1), "every element owned exactly once");
    }

    #[test]
    fn edge_tiles_are_identity_padded() {
        let d = desc(5, 5, 4, 1, 1);
        let a = DistMatrix::from_fn(d, 0, 0, |_, _| 7.0f64);
        // Tile (1,1) holds global rows/cols 4..8; only (4,4) is real.
        let t = a.global_tile(1, 1);
        assert_eq!(t[0], 7.0); // (4,4) real
        assert_eq!(t[1 * 4 + 1], 1.0); // (5,5) pad diagonal
        assert_eq!(t[1 * 4 + 2], 0.0); // (5,6) pad off-diagonal
        // Tile (0,1): rows 0..4, cols 4..8; col 4 real, rest zero pad
        // (off the global diagonal except (4,4) which is not in this tile).
        let t = a.global_tile(0, 1);
        assert_eq!(t[0], 7.0); // (0,4) real
        assert_eq!(t[1], 0.0); // (0,5) pad
    }

    #[test]
    fn global_tile_addressing_matches_local() {
        let d = desc(16, 16, 4, 2, 2);
        let mut a = DistMatrix::from_fn(d, 1, 0, |i, j| (i + j) as f64);
        // rank (1,0) owns tile rows {1,3}, tile cols {0,2}
        assert!(a.owns_tile_row(1) && a.owns_tile_row(3));
        assert!(!a.owns_tile_row(0));
        assert!(a.owns_tile_col(2) && !a.owns_tile_col(1));
        let via_global = a.global_tile(3, 2).to_vec();
        assert_eq!(via_global, a.tile(1, 1));
        a.global_tile_mut(3, 2)[0] = -1.0;
        assert_eq!(a.tile(1, 1)[0], -1.0);
    }

    #[test]
    fn owned_tiles_enumerates_all_local_tiles() {
        let d = desc(20, 12, 4, 2, 3);
        let a = DistMatrix::<f32>::zeros(d, 0, 2);
        let tiles: Vec<_> = a.owned_tiles().collect();
        assert_eq!(tiles.len(), a.local_mt() * a.local_nt());
        for (lti, ltj, ti, tj) in tiles {
            assert_eq!(ti % 2, 0);
            assert_eq!(tj % 3, 2);
            assert_eq!(d.local_ti(ti), lti);
            assert_eq!(d.local_tj(tj), ltj);
        }
    }
}
