//! Data distribution (CUPLSS level 3): the 2-D block-cyclic layout every
//! solver and PBLAS routine in this crate operates on.
//!
//! The layer has three parts:
//!
//! * [`BlockDesc`] (aliased [`Descriptor`]) — the layout contract: global
//!   shape, tile size and process-grid extents, with pure-arithmetic
//!   global↔local↔owner index maps.  Operand conformability is descriptor
//!   equality — the shape validation every consumer performs.
//! * [`DistMatrix`] / [`DistVector`] — one rank's shard: identity-padded
//!   `tile x tile` matrix tiles and zero-padded, column-replicated vector
//!   blocks.  Fixed tile shapes are what let every local op dispatch to an
//!   AOT-compiled [`crate::accel::Engine`] executable.
//! * redistribution ([`gather_matrix`], [`scatter_matrix`],
//!   [`gather_vector`], [`scatter_vector`], [`ptranspose`]) — host↔cluster
//!   movement and the transpose (row↔column) exchange, all as real messages
//!   through [`crate::comm`] so the virtual clock sees the traffic.
//!
//! Layout recap for a 4-tile-square matrix on a 2x2 mesh (rank = `(row,col)`
//! owning tile `(ti mod 2, tj mod 2)`):
//!
//! ```text
//!        tj=0      tj=1      tj=2      tj=3
//! ti=0  (0,0)     (0,1)     (0,0)     (0,1)
//! ti=1  (1,0)     (1,1)     (1,0)     (1,1)
//! ti=2  (0,0)     (0,1)     (0,0)     (0,1)
//! ti=3  (1,0)     (1,1)     (1,0)     (1,1)
//! ```
//!
//! Vectors follow the tile rows (block `ti` on process row `ti mod 2`),
//! replicated across the process columns — see `DESIGN.md` §2 for why that
//! layout makes every Krylov recurrence communication-minimal.  The sparse
//! operand format ([`crate::sparse::DistCsrMatrix`]) reuses this same rule
//! for its *rows*, which is what lets it pair with [`DistVector`] without
//! any new descriptor machinery (`DESIGN.md` §10).

pub mod descriptor;
pub mod matrix;
pub mod multivector;
pub mod redistribute;
pub mod vector;

pub use descriptor::{ceil_div, BlockDesc, Descriptor};
pub use matrix::DistMatrix;
pub use multivector::DistMultiVector;
pub use redistribute::{
    gather_matrix, gather_vector, ptranspose, scatter_matrix, scatter_vector,
};
pub use vector::DistVector;
