//! The logical 2-D process mesh (CUPLSS "uses a logical bidimensional mesh of
//! processors").
//!
//! `P` ranks are arranged as a `pr x pc` grid in row-major order:
//! rank = row * pc + col.  The factorisation is chosen as close to square as
//! possible (`pr <= pc`), the standard choice for block-cyclic dense linear
//! algebra because it balances row- and column-communicator sizes.

use crate::comm::{Comm, Group};
use crate::Scalar;

/// Shape and coordinates of the 2-D mesh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MeshShape {
    /// Process rows.
    pub pr: usize,
    /// Process columns.
    pub pc: usize,
}

impl MeshShape {
    /// Near-square factorisation of `p` with `pr <= pc`.
    pub fn near_square(p: usize) -> Self {
        assert!(p > 0);
        let mut pr = (p as f64).sqrt() as usize;
        while pr > 1 && p % pr != 0 {
            pr -= 1;
        }
        let pr = pr.max(1);
        MeshShape { pr, pc: p / pr }
    }

    /// Explicit shape (validated).
    pub fn new(pr: usize, pc: usize) -> Self {
        assert!(pr > 0 && pc > 0);
        MeshShape { pr, pc }
    }

    /// Total ranks.
    pub fn size(&self) -> usize {
        self.pr * self.pc
    }

    /// (row, col) of a world rank.
    pub fn coords(&self, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.size());
        (rank / self.pc, rank % self.pc)
    }

    /// World rank at (row, col).
    pub fn rank_at(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.pr && col < self.pc);
        row * self.pc + col
    }

    /// World ranks of process row `row` (a row communicator's members).
    pub fn row_ranks(&self, row: usize) -> Vec<usize> {
        (0..self.pc).map(|c| self.rank_at(row, c)).collect()
    }

    /// World ranks of process column `col`.
    pub fn col_ranks(&self, col: usize) -> Vec<usize> {
        (0..self.pr).map(|r| self.rank_at(r, col)).collect()
    }
}

/// A rank's view of the mesh: its coordinates plus row/column communicators.
pub struct Mesh<'a, S: Scalar> {
    comm: &'a Comm<S>,
    shape: MeshShape,
    row: usize,
    col: usize,
}

impl<'a, S: Scalar> Mesh<'a, S> {
    /// Build the mesh view for this rank.  `comm.size()` must equal
    /// `shape.size()`.
    pub fn new(comm: &'a Comm<S>, shape: MeshShape) -> Self {
        assert_eq!(
            comm.size(),
            shape.size(),
            "mesh {}x{} needs exactly {} ranks",
            shape.pr,
            shape.pc,
            shape.size()
        );
        let (row, col) = shape.coords(comm.rank());
        Mesh { comm, shape, row, col }
    }

    /// Near-square mesh over the whole world.
    pub fn near_square(comm: &'a Comm<S>) -> Self {
        Self::new(comm, MeshShape::near_square(comm.size()))
    }

    /// Mesh shape.
    pub fn shape(&self) -> MeshShape {
        self.shape
    }

    /// This rank's process row.
    pub fn row(&self) -> usize {
        self.row
    }

    /// This rank's process column.
    pub fn col(&self) -> usize {
        self.col
    }

    /// The underlying endpoint.
    pub fn comm(&self) -> &'a Comm<S> {
        self.comm
    }

    /// Row communicator: all ranks in this rank's process row
    /// (group rank == process column).
    pub fn row_comm(&self) -> Group<'a, S> {
        self.comm.group(&self.shape.row_ranks(self.row))
    }

    /// Column communicator: all ranks in this rank's process column
    /// (group rank == process row).
    pub fn col_comm(&self) -> Group<'a, S> {
        self.comm.group(&self.shape.col_ranks(self.col))
    }

    /// World communicator.
    pub fn world(&self) -> Group<'a, S> {
        self.comm.world()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{NetworkModel, Payload, Tag, World};

    #[test]
    fn near_square_shapes() {
        assert_eq!(MeshShape::near_square(1), MeshShape { pr: 1, pc: 1 });
        assert_eq!(MeshShape::near_square(2), MeshShape { pr: 1, pc: 2 });
        assert_eq!(MeshShape::near_square(4), MeshShape { pr: 2, pc: 2 });
        assert_eq!(MeshShape::near_square(8), MeshShape { pr: 2, pc: 4 });
        assert_eq!(MeshShape::near_square(16), MeshShape { pr: 4, pc: 4 });
        assert_eq!(MeshShape::near_square(6), MeshShape { pr: 2, pc: 3 });
        assert_eq!(MeshShape::near_square(7), MeshShape { pr: 1, pc: 7 });
    }

    #[test]
    fn coords_roundtrip() {
        let m = MeshShape::new(3, 4);
        for rank in 0..m.size() {
            let (r, c) = m.coords(rank);
            assert_eq!(m.rank_at(r, c), rank);
        }
    }

    #[test]
    fn row_col_ranks_partition() {
        let m = MeshShape::new(2, 3);
        let mut all: Vec<usize> = (0..2).flat_map(|r| m.row_ranks(r)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..6).collect::<Vec<_>>());
        let mut all: Vec<usize> = (0..3).flat_map(|c| m.col_ranks(c)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn row_comm_communicates_within_row() {
        let out = World::run::<f64, _, _>(6, NetworkModel::ideal(), |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 3));
            // Column 0 of each row broadcasts its world rank along the row.
            let g = mesh.row_comm();
            let data = if mesh.col() == 0 {
                Some(Payload::Scalar(comm.rank() as f64))
            } else {
                None
            };
            g.bcast(0, 1, data).into_scalar()
        });
        assert_eq!(out, vec![0.0, 0.0, 0.0, 3.0, 3.0, 3.0]);
    }

    #[test]
    fn col_comm_communicates_within_col() {
        let out = World::run::<f64, _, _>(6, NetworkModel::ideal(), |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 3));
            let g = mesh.col_comm();
            use crate::comm::collectives::ReduceOp;
            g.allreduce_scalar(2, comm.rank() as f64, ReduceOp::Sum)
        });
        // columns are {0,3}, {1,4}, {2,5} -> sums 3, 5, 7
        assert_eq!(out, vec![3.0, 5.0, 7.0, 3.0, 5.0, 7.0]);
    }

    #[test]
    fn cross_row_p2p_via_world() {
        let out = World::run::<f64, _, _>(4, NetworkModel::ideal(), |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
            // (0,0) sends to (1,1) directly.
            if (mesh.row(), mesh.col()) == (0, 0) {
                comm.send(mesh.shape().rank_at(1, 1), Tag::P2p(0), Payload::Scalar(9.0));
                0.0
            } else if (mesh.row(), mesh.col()) == (1, 1) {
                comm.recv(0, Tag::P2p(0)).into_scalar()
            } else {
                -1.0
            }
        });
        assert_eq!(out[3], 9.0);
    }
}
