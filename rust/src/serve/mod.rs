//! The solve-request serving layer: admit a stream of solve requests
//! (mixed kernels, sizes, tolerances), batch compatible ones, schedule the
//! batches over the cluster, and report throughput + latency percentiles.
//!
//! Batching is the whole point: requests sharing an operator — same
//! [`Workload`], size and [`Method`] — ride **one** factorization (direct
//! methods, [`crate::solvers::plu_solve_panel`]) or shared matvec sweeps
//! (blocked Krylov, [`crate::solvers::block_cg`]), so a batch of k costs
//! far less than k solos.  Tolerances may differ within a batch: the block
//! solvers converge per column.  The scheduler is deliberately simple —
//! FIFO, batching only *consecutive* compatible requests up to
//! [`ServeConfig::rhs_batch`] — so the reported latencies are honest (no
//! reordering a real queue could not do) and the batched-vs-solo A/B
//! (`--no-batching`) isolates exactly the amortization.
//!
//! The timeline is virtual: a batch starts when the cluster is free *and*
//! its last member has arrived, and runs for the batch's virtual-clock
//! makespan.  Latency = finish − arrival.  [`schedule`] is generic over
//! how a batch is priced — the CLI runs the live cluster simulation
//! ([`serve_cluster`]), the serving bench prices batches with the analytic
//! model twins — so the queueing/percentile arithmetic is shared (and
//! mirrored by the python oracle).

use crate::accel::EngineKind;
use crate::cluster::{Cluster, ClusterConfig, Method};
use crate::comm::FaultPlan;
use crate::workloads::Workload;
use crate::{Error, Result, Scalar};

/// One solve request admitted to the serving layer.
#[derive(Clone, Copy, Debug)]
pub struct SolveRequest {
    /// Stream-unique id (drives the deterministic RHS coefficient).
    pub id: usize,
    /// Operator family.
    pub workload: Workload,
    /// Problem size.
    pub n: usize,
    /// Solver.
    pub method: Method,
    /// Relative residual target (iterative methods; direct solves ignore).
    pub tol: f64,
    /// Arrival time on the virtual timeline, seconds.
    pub arrival: f64,
}

impl SolveRequest {
    /// Two requests may share a batch iff they share the operator: same
    /// workload, size and method (tolerance may differ — the block solvers
    /// converge per column).
    pub fn compatible(&self, other: &SolveRequest) -> bool {
        self.workload == other.workload && self.n == other.n && self.method == other.method
    }

    /// The request's deterministic RHS coefficient: `b = coeff · b0`, so
    /// the known answer is `coeff · x_true`.  `1 + id%8 / 8` is exact in
    /// floating point — error checks stay as tight as the base workload's.
    pub fn rhs_coeff(&self) -> f64 {
        1.0 + 0.125 * (self.id % 8) as f64
    }
}

/// Scheduler knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Max right-hand sides per batch (the RHS-panel width cap).
    pub rhs_batch: usize,
    /// The A/B switch: `false` forces singleton batches (`--no-batching`),
    /// pricing the same stream without any amortization.
    pub batching: bool,
    /// Cross-request factorization cache ([`crate::cluster::FactorCache`]):
    /// a later batch naming an operator a previous batch already factored
    /// (same workload, size, direct method) pays only the substitutions.
    /// Orthogonal to `batching` — batching amortizes *within* a batch, the
    /// cache *across* batches.
    pub factor_cache: bool,
    /// Max distinct operators the factor cache tracks, LRU-evicted beyond
    /// it.  The default (`usize::MAX`) is the old unbounded seen-forever
    /// behaviour, byte for byte.
    pub factor_cache_cap: usize,
    /// Per-request latency deadline, seconds: a request whose batch
    /// finishes more than this after its arrival counts as a deadline miss
    /// ([`ServeReport::deadline_misses`]).  `None` disables the check.
    pub deadline: Option<f64>,
    /// Failed batch attempts to retry before falling back to the degraded
    /// arm.  0 (the default) goes straight to degraded on the first
    /// failure.
    pub retry_budget: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            rhs_batch: 8,
            batching: true,
            factor_cache: true,
            factor_cache_cap: usize::MAX,
            deadline: None,
            retry_budget: 0,
        }
    }
}

/// Per-attempt context handed to the batch pricer ([`schedule`]'s
/// `run_batch`).
#[derive(Clone, Copy, Debug)]
pub struct BatchCtx {
    /// An earlier batch on this timeline already factored this operator
    /// (direct methods with [`ServeConfig::factor_cache`] on).
    pub factor_cached: bool,
    /// 0 on the first attempt; incremented per retry after a failure.
    pub attempt: usize,
    /// Last-resort attempt with the retry budget exhausted: the pricer
    /// should degrade — run the host arm instead of the faulted device
    /// path.  An error from a degraded attempt fails the whole run.
    pub degraded: bool,
}

/// A deterministic mixed demo stream: groups of four consecutive requests
/// share an operator (so batching has something to merge), methods cycle
/// LU / CG / Cholesky / BiCGSTAB across groups, sizes cycle
/// `base_n · {1,2,3}`, tolerances alternate 1e-6 / 1e-8 within a group,
/// and arrivals tick every 2 ms.  Pure arithmetic — no RNG, no clock — so
/// the rust bench and the python oracle generate the identical stream.
pub fn demo_stream(len: usize, base_n: usize) -> Vec<SolveRequest> {
    use crate::solvers::IterMethod;
    (0..len)
        .map(|i| {
            let group = i / 4;
            let method = match group % 4 {
                0 => Method::Lu,
                1 => Method::Iterative(IterMethod::Cg),
                2 => Method::Cholesky,
                _ => Method::Iterative(IterMethod::Bicgstab),
            };
            let workload = match method {
                Method::Cholesky | Method::Iterative(IterMethod::Cg) => Workload::Spd,
                _ => Workload::DiagDominant,
            };
            SolveRequest {
                id: i,
                workload,
                n: base_n * (1 + group % 3),
                method,
                tol: if i % 2 == 0 { 1e-6 } else { 1e-8 },
                arrival: 0.002 * i as f64,
            }
        })
        .collect()
}

/// Group a (arrival-ordered) request stream into batches: FIFO, merging
/// only *consecutive* compatible requests, capped at `rhs_batch` (1 when
/// batching is off).  Returns index groups into `requests`.
pub fn form_batches(requests: &[SolveRequest], cfg: &ServeConfig) -> Vec<Vec<usize>> {
    let cap = if cfg.batching { cfg.rhs_batch.max(1) } else { 1 };
    let mut batches: Vec<Vec<usize>> = Vec::new();
    for i in 0..requests.len() {
        match batches.last_mut() {
            Some(batch)
                if batch.len() < cap
                    && requests[batch[0]].compatible(&requests[i]) =>
            {
                batch.push(i);
            }
            _ => batches.push(vec![i]),
        }
    }
    batches
}

/// What running one batch cost — produced by the pricing closure
/// ([`schedule`]'s `run_batch`): the live cluster simulation or the
/// analytic model.
#[derive(Clone, Debug)]
pub struct BatchCost {
    /// Virtual-clock makespan of the batched solve.
    pub makespan: f64,
    /// Per-request attributed virtual seconds (own bucket + even share of
    /// the batch's shared bucket); empty if attribution is unavailable.
    pub per_request_secs: Vec<f64>,
    /// Max abs solution error across the batch vs the known answers.
    pub max_err: f64,
    /// The pricer itself degraded mid-batch (e.g. mixed-precision
    /// stagnation forced the reported wide fallback) — the batch's
    /// requests count as degraded even on a first, un-retried attempt.
    pub degraded: bool,
}

/// One request's fate on the serving timeline.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    /// Request id.
    pub id: usize,
    /// Solver name.
    pub method: &'static str,
    /// Problem size.
    pub n: usize,
    /// Arrival time, seconds.
    pub arrival: f64,
    /// When its batch started executing.
    pub start: f64,
    /// When its batch finished.
    pub finish: f64,
    /// Index of the batch it rode in.
    pub batch: usize,
    /// Attributed virtual seconds (0 when attribution was unavailable).
    pub attributed_secs: f64,
    /// Max abs error of the whole batch (requests share the check).
    pub max_err: f64,
    /// The batch finished past this request's deadline
    /// ([`ServeConfig::deadline`]; always `false` with no deadline set).
    pub deadline_missed: bool,
}

impl RequestOutcome {
    /// Queueing + execution latency: finish − arrival.
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }
}

/// The serving run's ledger.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Per-request outcomes, in stream order.
    pub outcomes: Vec<RequestOutcome>,
    /// Batches executed.
    pub batches: usize,
    /// Batches that rode the cross-request factor cache (0 with
    /// `factor_cache` off or when no operator repeats).
    pub factor_cache_hits: usize,
    /// Operators LRU-evicted from the bounded factor cache
    /// ([`ServeConfig::factor_cache_cap`]; 0 at the unbounded default).
    pub factor_cache_evictions: usize,
    /// Requests whose batch finished past their deadline (0 with no
    /// deadline configured).
    pub deadline_misses: usize,
    /// Requests whose batch needed at least one retry.
    pub retried_requests: usize,
    /// Requests served by a degraded attempt (host-arm fallback) or whose
    /// pricer reported in-batch degradation ([`BatchCost::degraded`]).
    pub degraded_requests: usize,
}

impl ServeReport {
    /// Completed requests per virtual second: stream length over the span
    /// from first arrival to last finish.
    pub fn throughput(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        let first = self.outcomes.iter().map(|o| o.arrival).fold(f64::INFINITY, f64::min);
        let last = self.outcomes.iter().map(|o| o.finish).fold(0.0f64, f64::max);
        if last > first {
            self.outcomes.len() as f64 / (last - first)
        } else {
            0.0
        }
    }

    /// Nearest-rank latency percentile (`q` in (0, 1]): the smallest
    /// latency ≥ that fraction of the distribution.
    pub fn latency_percentile(&self, q: f64) -> f64 {
        let mut lats: Vec<f64> = self.outcomes.iter().map(|o| o.latency()).collect();
        if lats.is_empty() {
            return 0.0;
        }
        lats.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        let idx = ((q * lats.len() as f64).ceil() as usize).clamp(1, lats.len()) - 1;
        lats[idx]
    }

    /// Median latency.
    pub fn p50(&self) -> f64 {
        self.latency_percentile(0.50)
    }

    /// Tail latency.
    pub fn p95(&self) -> f64 {
        self.latency_percentile(0.95)
    }

    /// Worst latency.
    pub fn latency_max(&self) -> f64 {
        self.outcomes.iter().map(|o| o.latency()).fold(0.0f64, f64::max)
    }

    /// Worst solution error across all batches.
    pub fn max_err(&self) -> f64 {
        self.outcomes.iter().map(|o| o.max_err).fold(0.0f64, f64::max)
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let robustness = if self.deadline_misses + self.retried_requests + self.degraded_requests
            + self.factor_cache_evictions
            > 0
        {
            format!(
                ", {} deadline misses, {} retried, {} degraded, {} evictions",
                self.deadline_misses,
                self.retried_requests,
                self.degraded_requests,
                self.factor_cache_evictions
            )
        } else {
            String::new()
        };
        format!(
            "{} requests in {} batches ({} factor-cache hits): {:.3} req/s, \
             latency p50 {} p95 {} max {}, err {:.2e}{}",
            self.outcomes.len(),
            self.batches,
            self.factor_cache_hits,
            self.throughput(),
            crate::util::fmt::secs(self.p50()),
            crate::util::fmt::secs(self.p95()),
            crate::util::fmt::secs(self.latency_max()),
            self.max_err(),
            robustness,
        )
    }
}

/// Run the serving timeline: form batches, price each with `run_batch`,
/// advance the virtual clock (a batch starts when the cluster is free and
/// its last member has arrived), and ledger every request.  `requests`
/// must be arrival-ordered (the FIFO contract).
///
/// `run_batch` receives the batch plus a [`BatchCtx`]: whether an earlier
/// batch on this timeline already factored the same operator (direct
/// methods with [`ServeConfig::factor_cache`] on), which attempt this is,
/// and whether the retry budget is spent (the degraded last resort).  The
/// scheduler tracks cache hits itself — a capacity-bounded LRU over
/// `(workload, n, method)` — so the live-cluster path and the analytic
/// model twins price the *same* batches as hits.
///
/// A failing batch is retried up to [`ServeConfig::retry_budget`] times,
/// then re-attempted once degraded; only a degraded failure propagates.
/// Failed attempts cost nothing on the virtual timeline (an `Err` carries
/// no makespan) — the robustness ledger, not the clock, records them.
pub fn schedule<F>(
    requests: &[SolveRequest],
    cfg: &ServeConfig,
    mut run_batch: F,
) -> Result<ServeReport>
where
    F: FnMut(&[&SolveRequest], BatchCtx) -> Result<BatchCost>,
{
    if requests.windows(2).any(|w| w[0].arrival > w[1].arrival) {
        return Err(Error::config("serve requests must be arrival-ordered".to_string()));
    }
    let batches = form_batches(requests, cfg);
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(requests.len());
    let mut clock = 0.0f64;
    // LRU over operators: front = least recently used.  At the unbounded
    // default capacity this is the old seen-forever set, hit for hit.
    let mut seen: Vec<(Workload, usize, &'static str)> = Vec::new();
    let mut factor_cache_hits = 0usize;
    let mut factor_cache_evictions = 0usize;
    let mut deadline_misses = 0usize;
    let mut retried_requests = 0usize;
    let mut degraded_requests = 0usize;
    for (bi, batch) in batches.iter().enumerate() {
        let members: Vec<&SolveRequest> = batch.iter().map(|&i| &requests[i]).collect();
        let head = members[0];
        let key = (head.workload, head.n, head.method.name());
        let factor_cached = cfg.factor_cache
            && matches!(head.method, Method::Lu | Method::Cholesky)
            && match seen.iter().position(|k| *k == key) {
                Some(pos) => {
                    // A hit refreshes recency.
                    seen.remove(pos);
                    seen.push(key);
                    true
                }
                None => {
                    seen.push(key);
                    while seen.len() > cfg.factor_cache_cap {
                        seen.remove(0);
                        factor_cache_evictions += 1;
                    }
                    false
                }
            };
        if factor_cached {
            factor_cache_hits += 1;
        }
        let mut attempt = 0usize;
        let mut degraded = false;
        let cost = loop {
            match run_batch(&members, BatchCtx { factor_cached, attempt, degraded }) {
                Ok(c) => break c,
                Err(e) if degraded => return Err(e),
                Err(_) if attempt < cfg.retry_budget => attempt += 1,
                Err(_) => degraded = true,
            }
        };
        if attempt > 0 {
            retried_requests += members.len();
        }
        if degraded || cost.degraded {
            degraded_requests += members.len();
        }
        let ready = members.iter().map(|r| r.arrival).fold(0.0f64, f64::max);
        let start = clock.max(ready);
        let finish = start + cost.makespan;
        clock = finish;
        for (j, r) in members.iter().enumerate() {
            let deadline_missed = cfg.deadline.map_or(false, |d| finish - r.arrival > d);
            if deadline_missed {
                deadline_misses += 1;
            }
            outcomes.push(RequestOutcome {
                id: r.id,
                method: r.method.name(),
                n: r.n,
                arrival: r.arrival,
                start,
                finish,
                batch: bi,
                attributed_secs: cost.per_request_secs.get(j).copied().unwrap_or(0.0),
                max_err: cost.max_err,
                deadline_missed,
            });
        }
    }
    Ok(ServeReport {
        outcomes,
        batches: batches.len(),
        factor_cache_hits,
        factor_cache_evictions,
        deadline_misses,
        retried_requests,
        degraded_requests,
    })
}

/// Serve a request stream over the live cluster simulation: each batch is
/// one [`Cluster::solve_batch_cached`] call (shared factorization / blocked
/// Krylov, per-request attribution enabled, and — with
/// [`ServeConfig::factor_cache`] on — the cluster's cross-request factor
/// cache).  On a fresh cluster the cluster-side cache hits exactly the
/// batches the scheduler's seen-set predicts.
pub fn serve_cluster<S: Scalar>(
    cluster: &Cluster,
    requests: &[SolveRequest],
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    // Bound the cluster-side cache to match the scheduler's LRU, so what
    // the scheduler predicts as evicted really is re-factored.
    cluster.factor_cache().set_capacity(cfg.factor_cache_cap);
    // Degraded arm, built on first use: a device fault (e.g. a crash with
    // no checkpoint) falls back to the host engine with a clean fault
    // plan — the recovery path, not another roll of the same dice.
    let mut degraded_cluster: Option<Cluster> = None;
    schedule(requests, cfg, |members, ctx| {
        let head = members[0];
        let coeffs: Vec<f64> = members.iter().map(|r| r.rhs_coeff()).collect();
        let tols: Vec<f64> = members.iter().map(|r| r.tol).collect();
        let target: &Cluster = if ctx.degraded {
            if degraded_cluster.is_none() {
                degraded_cluster = Some(Cluster::new(ClusterConfig {
                    engine: EngineKind::CpuSerial,
                    fault_plan: FaultPlan::default(),
                    ..cluster.config().clone()
                })?);
            }
            degraded_cluster.as_ref().expect("just built")
        } else {
            cluster
        };
        let report = target.solve_batch_cached::<S>(
            head.workload,
            head.n,
            head.method,
            &coeffs,
            &tols,
            cfg.factor_cache && !ctx.degraded,
        )?;
        Ok(BatchCost {
            makespan: report.makespan(),
            per_request_secs: report.per_request_secs(),
            max_err: report.max_err,
            // Mixed-precision stagnation already re-ran wide inside the
            // batch: report it so the ledger counts the degradation.
            degraded: report.mixed_fallback,
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::IterMethod;

    #[test]
    fn demo_stream_is_deterministic_and_mixed() {
        let s = demo_stream(16, 64);
        assert_eq!(s.len(), 16);
        // Groups of four share an operator...
        assert!(s[0].compatible(&s[3]));
        assert_eq!(s[0].method, Method::Lu);
        assert_eq!(s[4].method, Method::Iterative(IterMethod::Cg));
        assert_eq!(s[8].method, Method::Cholesky);
        assert_eq!(s[12].method, Method::Iterative(IterMethod::Bicgstab));
        // ...across groups the operator changes.
        assert!(!s[3].compatible(&s[4]));
        // SPD methods get SPD workloads.
        assert_eq!(s[4].workload, Workload::Spd);
        assert_eq!(s[0].workload, Workload::DiagDominant);
        // Arrivals tick and tolerances alternate.
        assert!(s[1].arrival > s[0].arrival);
        assert_ne!(s[0].tol, s[1].tol);
        // Identical on every call.
        let t = demo_stream(16, 64);
        assert_eq!(s[7].n, t[7].n);
        assert_eq!(s[7].arrival, t[7].arrival);
    }

    #[test]
    fn batches_merge_only_consecutive_compatible_requests() {
        let s = demo_stream(9, 64);
        let b = form_batches(&s, &ServeConfig::default());
        assert_eq!(b, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8]]);
        // Cap splits a group.
        let b2 = form_batches(&s, &ServeConfig { rhs_batch: 3, ..ServeConfig::default() });
        assert_eq!(b2[0], vec![0, 1, 2]);
        assert_eq!(b2[1], vec![3]);
        // Batching off: singletons.
        let b1 = form_batches(&s, &ServeConfig { batching: false, ..ServeConfig::default() });
        assert_eq!(b1.len(), 9);
        assert!(b1.iter().all(|g| g.len() == 1));
    }

    #[test]
    fn schedule_timeline_and_percentiles() {
        let s = demo_stream(8, 64);
        // Price every batch at 1 virtual second, regardless of width.
        let rep = schedule(&s, &ServeConfig::default(), |members, _ctx| {
            Ok(BatchCost {
                makespan: 1.0,
                per_request_secs: vec![0.25; members.len()],
                max_err: 1e-12,
                degraded: false,
            })
        })
        .unwrap();
        assert_eq!(rep.batches, 2);
        // An 8-request demo stream never repeats an operator.
        assert_eq!(rep.factor_cache_hits, 0);
        // Batch 0 waits for request 3 (arrival 0.006), then runs 1 s.
        assert_eq!(rep.outcomes[0].start, 0.006);
        assert_eq!(rep.outcomes[0].finish, 1.006);
        // Batch 1's members all arrived before the cluster freed up.
        assert_eq!(rep.outcomes[4].start, 1.006);
        assert_eq!(rep.outcomes[4].finish, 2.006);
        // Latency = finish − arrival; max is the last batch's first member.
        assert!((rep.outcomes[4].latency() - (2.006 - 0.008)).abs() < 1e-12);
        assert_eq!(rep.latency_max(), rep.outcomes[4].latency());
        // Nearest-rank percentiles: p50 of 8 = 4th smallest; max = p100.
        assert_eq!(rep.latency_percentile(1.0), rep.latency_max());
        assert!(rep.p50() <= rep.p95() && rep.p95() <= rep.latency_max());
        // Throughput spans first arrival to last finish.
        assert!((rep.throughput() - 8.0 / 2.006).abs() < 1e-9);
        assert_eq!(rep.outcomes[3].attributed_secs, 0.25);
    }

    #[test]
    fn schedule_rejects_unordered_streams() {
        let mut s = demo_stream(4, 64);
        s.swap(0, 3);
        assert!(schedule(&s, &ServeConfig::default(), |_, _| Ok(BatchCost {
            makespan: 1.0,
            per_request_secs: vec![],
            max_err: 0.0,
            degraded: false,
        }))
        .is_err());
    }

    #[test]
    fn scheduler_flags_repeat_direct_operators_as_cache_hits() {
        // 64 requests = 16 groups: LU revisits (DiagDominant, base·1) at
        // group 12 and Cholesky revisits (Spd, base·3) at group 14 — the
        // iterative groups never count, whatever they repeat.
        let s = demo_stream(64, 32);
        let mut flagged = Vec::new();
        let rep = schedule(&s, &ServeConfig::default(), |members, ctx| {
            if ctx.factor_cached {
                flagged.push((members[0].method.name(), members[0].n));
            }
            Ok(BatchCost { makespan: 1.0, per_request_secs: vec![], max_err: 0.0, degraded: false })
        })
        .unwrap();
        assert_eq!(rep.factor_cache_hits, 2);
        assert_eq!(rep.factor_cache_evictions, 0);
        assert_eq!(flagged, vec![("LU", 32), ("Cholesky", 96)]);
        // The A/B arm: same stream, no cache, no hits.
        let off = ServeConfig { factor_cache: false, ..ServeConfig::default() };
        let rep = schedule(&s, &off, |_, ctx| {
            assert!(!ctx.factor_cached);
            Ok(BatchCost { makespan: 1.0, per_request_secs: vec![], max_err: 0.0, degraded: false })
        })
        .unwrap();
        assert_eq!(rep.factor_cache_hits, 0);
    }

    #[test]
    fn bounded_scheduler_cache_evicts_lru_operators() {
        // 64 requests touch 6 distinct operators; a capacity-1 LRU forgets
        // each direct operator before its group-12/14 revisit, so the hits
        // the unbounded default reports become misses — and every push past
        // capacity is an eviction.
        let s = demo_stream(64, 32);
        let tight = ServeConfig { factor_cache_cap: 1, ..ServeConfig::default() };
        let rep = schedule(&s, &tight, |_, _ctx| {
            Ok(BatchCost { makespan: 1.0, per_request_secs: vec![], max_err: 0.0, degraded: false })
        })
        .unwrap();
        assert_eq!(rep.factor_cache_hits, 0);
        // Only direct-method batches enter the LRU: 8 direct groups (LU and
        // Cholesky alternate among the 16), each evicting its predecessor.
        assert_eq!(rep.factor_cache_evictions, 7);
        // A capacity that holds the working set behaves like the default.
        let roomy = ServeConfig { factor_cache_cap: 8, ..ServeConfig::default() };
        let rep = schedule(&s, &roomy, |_, _ctx| {
            Ok(BatchCost { makespan: 1.0, per_request_secs: vec![], max_err: 0.0, degraded: false })
        })
        .unwrap();
        assert_eq!(rep.factor_cache_hits, 2);
        assert_eq!(rep.factor_cache_evictions, 0);
    }

    #[test]
    fn retry_budget_then_degraded_fallback_is_ledgered() {
        let s = demo_stream(4, 64); // one batch of 4
        let cfg = ServeConfig { retry_budget: 2, ..ServeConfig::default() };
        let mut attempts = Vec::new();
        let rep = schedule(&s, &cfg, |members, ctx| {
            attempts.push((ctx.attempt, ctx.degraded));
            if !ctx.degraded {
                return Err(Error::Runtime("device fault".to_string()));
            }
            Ok(BatchCost {
                makespan: 1.0,
                per_request_secs: vec![0.25; members.len()],
                max_err: 1e-12,
                degraded: false,
            })
        })
        .unwrap();
        // Attempt 0, two retries, then the degraded last resort.
        assert_eq!(attempts, vec![(0, false), (1, false), (2, false), (2, true)]);
        assert_eq!(rep.retried_requests, 4);
        assert_eq!(rep.degraded_requests, 4);
        // Failed attempts cost nothing on the timeline: the batch still
        // starts at its last arrival and runs one priced makespan.
        assert_eq!(rep.outcomes[0].start, 0.006);
        assert_eq!(rep.outcomes[0].finish, 1.006);

        // A degraded failure propagates instead of looping.
        let err = schedule(&s, &cfg, |_, _ctx| -> Result<BatchCost> {
            Err(Error::Runtime("unrecoverable".to_string()))
        });
        assert!(err.is_err());

        // A pricer-reported in-batch degradation counts without any retry.
        let rep = schedule(&s, &ServeConfig::default(), |members, _ctx| {
            Ok(BatchCost {
                makespan: 1.0,
                per_request_secs: vec![0.25; members.len()],
                max_err: 1e-12,
                degraded: true,
            })
        })
        .unwrap();
        assert_eq!(rep.retried_requests, 0);
        assert_eq!(rep.degraded_requests, 4);
    }

    #[test]
    fn deadlines_count_late_finishes_per_request() {
        let s = demo_stream(8, 64); // two batches, finishes 1.006 and 2.006
        let cfg = ServeConfig { deadline: Some(1.05), ..ServeConfig::default() };
        let rep = schedule(&s, &cfg, |members, _ctx| {
            Ok(BatchCost {
                makespan: 1.0,
                per_request_secs: vec![0.25; members.len()],
                max_err: 1e-12,
                degraded: false,
            })
        })
        .unwrap();
        // Batch 0 latencies run 1.006 .. 1.000: all inside 1.05.  Batch 1
        // latencies run 1.998 .. 1.992: all late.
        assert_eq!(rep.deadline_misses, 4);
        assert!(rep.outcomes[..4].iter().all(|o| !o.deadline_missed));
        assert!(rep.outcomes[4..].iter().all(|o| o.deadline_missed));
        // Summary surfaces the robustness clause only when something fired.
        assert!(rep.summary().contains("4 deadline misses"));
        let quiet = schedule(&s, &ServeConfig::default(), |members, _ctx| {
            Ok(BatchCost {
                makespan: 1.0,
                per_request_secs: vec![0.25; members.len()],
                max_err: 1e-12,
                degraded: false,
            })
        })
        .unwrap();
        assert!(!quiet.summary().contains("deadline"));
    }
}
