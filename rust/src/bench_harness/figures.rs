//! Regeneration of the paper's Figures 3 and 4 (speedup vs ranks).
//!
//! Speedup is computed exactly as the paper defines it: parallel makespan
//! against "a serial version that uses one CPU" — i.e. the P = 1 /
//! CPU-engine arm is the common baseline for *both* the MPI+CUDA and the
//! MPI+ATLAS series.

use crate::accel::{ComputeProfile, EngineKind};
use crate::cluster::Method;
use crate::comm::NetworkModel;
use crate::mesh::MeshShape;
use crate::solvers::IterMethod;
use crate::util::fmt;
use crate::Scalar;

use super::model::{method_makespan, ModelParams};
use super::PAPER_RANKS;

/// One (ranks, makespan, speedup) sample.
#[derive(Clone, Copy, Debug)]
pub struct FigurePoint {
    /// Rank count.
    pub ranks: usize,
    /// Modelled (or measured) makespan, seconds.
    pub makespan: f64,
    /// Speedup over the serial CPU baseline.
    pub speedup: f64,
}

/// One labelled curve of a figure.
#[derive(Clone, Debug)]
pub struct FigureSeries {
    /// Legend label, e.g. "BiCGSTAB (MPI+CUDA)".
    pub label: String,
    /// Samples in rank order.
    pub points: Vec<FigurePoint>,
}

impl FigureSeries {
    /// Speedup at the largest rank count.
    pub fn final_speedup(&self) -> f64 {
        self.points.last().map(|p| p.speedup).unwrap_or(0.0)
    }
}

fn params_for(engine: EngineKind, ranks: usize, tile: usize, net: NetworkModel) -> ModelParams {
    ModelParams {
        tile,
        shape: MeshShape::near_square(ranks),
        net,
        engine: match engine {
            EngineKind::Accelerated => ComputeProfile::gtx280_cublas(),
            EngineKind::CpuSerial => ComputeProfile::q6600_atlas(),
        },
        panel_cpu: ComputeProfile::q6600_atlas(),
        // The paper's fixture is a general dense matrix: partial pivoting
        // interchanges on roughly half the elimination steps.
        swap_fraction: 0.5,
        device_mem: crate::accel::DEFAULT_DEVICE_MEM,
    }
}

/// Model-mode speedup series for one method over both engine arms.
pub fn speedup_series<S: Scalar>(
    method: Method,
    n: usize,
    iters: usize,
    restart: usize,
    tile: usize,
    net: NetworkModel,
    ranks: &[usize],
) -> Vec<FigureSeries> {
    // Common serial baseline: P = 1, CPU engine (the paper's "one CPU").
    let base = method_makespan::<S>(
        method,
        n,
        iters,
        restart,
        &params_for(EngineKind::CpuSerial, 1, tile, net),
    );
    [EngineKind::Accelerated, EngineKind::CpuSerial]
        .iter()
        .map(|&engine| {
            let points = ranks
                .iter()
                .map(|&p| {
                    let ms = method_makespan::<S>(
                        method,
                        n,
                        iters,
                        restart,
                        &params_for(engine, p, tile, net),
                    );
                    FigurePoint { ranks: p, makespan: ms, speedup: base / ms }
                })
                .collect();
            FigureSeries {
                label: format!("{} ({})", method.name(), engine.label()),
                points,
            }
        })
        .collect()
}

/// Figure 3: speedup of the iterative solvers (GMRES, BiCG, BiCGSTAB).
pub fn fig3_series<S: Scalar>(n: usize, iters: usize, tile: usize) -> Vec<FigureSeries> {
    let net = NetworkModel::gigabit_ethernet();
    let mut out = Vec::new();
    for m in [IterMethod::Gmres, IterMethod::Bicg, IterMethod::Bicgstab] {
        out.extend(speedup_series::<S>(
            Method::Iterative(m),
            n,
            iters,
            30,
            tile,
            net,
            PAPER_RANKS,
        ));
    }
    out
}

/// Figure 4: speedup of the LU direct solver (optionally Cholesky, E5).
pub fn fig4_series<S: Scalar>(n: usize, tile: usize, include_cholesky: bool) -> Vec<FigureSeries> {
    let net = NetworkModel::gigabit_ethernet();
    let mut out = speedup_series::<S>(Method::Lu, n, 0, 0, tile, net, PAPER_RANKS);
    if include_cholesky {
        out.extend(speedup_series::<S>(Method::Cholesky, n, 0, 0, tile, net, PAPER_RANKS));
    }
    out
}

/// Render series as the aligned table the bench binaries print.
pub fn render_table(title: &str, series: &[FigureSeries]) -> String {
    let mut header: Vec<String> = vec!["P".to_string()];
    for s in series {
        header.push(s.label.clone());
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let nrows = series.first().map(|s| s.points.len()).unwrap_or(0);
    let mut rows = Vec::with_capacity(nrows);
    for r in 0..nrows {
        let mut row = vec![series[0].points[r].ranks.to_string()];
        for s in series {
            row.push(format!("{:.2}", s.points[r].speedup));
        }
        rows.push(row);
    }
    let mut out = format!("== {title} ==\n");
    out.push_str(&fmt::table(&header_refs, &rows));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shape_matches_paper() {
        let series = fig4_series::<f32>(super::super::PAPER_N, 256, false);
        assert_eq!(series.len(), 2);
        let cuda = &series[0];
        let atlas = &series[1];
        assert!(cuda.label.contains("CUDA"));
        // Monotone increasing speedup with P for both arms.
        for s in &series {
            for w in s.points.windows(2) {
                assert!(
                    w[1].speedup > w[0].speedup,
                    "{}: speedup not monotone: {:?}",
                    s.label,
                    s.points
                );
            }
        }
        // CUDA arm above ATLAS arm at every P.
        for (c, a) in cuda.points.iter().zip(&atlas.points) {
            assert!(c.speedup >= a.speedup * 0.99, "CUDA {c:?} vs ATLAS {a:?}");
        }
        // Sub-linear at 16 ranks.
        assert!(cuda.final_speedup() < 16.0 * 40.0); // (CUDA baseline is CPU-serial, can exceed P)
        assert!(atlas.final_speedup() < 16.0);
    }

    #[test]
    fn fig3_lower_than_fig4_speedup() {
        // Paper §5: "The speedup is higher for the methods based on matrix
        // factorization compared with the iterative algorithms."  This holds
        // in the paper's headline MPI+CUDA configuration: LU's O(n³) BLAS-3
        // stream gains the full GPU compute advantage, while the iterative
        // methods' memory-bound matvecs gain little over the CPU.  (On the
        // pure-ATLAS arm our honestly-modelled iterative matvec scales
        // near-ideally and edges out LU's panel critical path — see
        // EXPERIMENTS.md E1/E2 discussion.)
        let f3 = fig3_series::<f32>(super::super::PAPER_N, 100, 256);
        let f4 = fig4_series::<f32>(super::super::PAPER_N, 256, false);
        let best_iter_cuda = f3
            .iter()
            .filter(|s| s.label.contains("CUDA"))
            .map(|s| s.final_speedup())
            .fold(0.0, f64::max);
        let lu_cuda = f4
            .iter()
            .find(|s| s.label.contains("CUDA"))
            .unwrap()
            .final_speedup();
        assert!(
            lu_cuda > best_iter_cuda,
            "LU {lu_cuda} must out-scale iterative {best_iter_cuda} in the CUDA arm"
        );
        // And the iterative CUDA gain over ATLAS is modest (the paper's
        // "this increase in the speedup is not very high").
        for m in ["GMRES", "BiCG (", "BiCGSTAB"] {
            let cuda = f3
                .iter()
                .find(|s| s.label.starts_with(m) && s.label.contains("CUDA"))
                .unwrap()
                .final_speedup();
            let atlas = f3
                .iter()
                .find(|s| s.label.starts_with(m) && s.label.contains("ATLAS"))
                .unwrap()
                .final_speedup();
            let gain = cuda / atlas;
            assert!(gain > 1.0 && gain < 2.0, "{m}: iterative CUDA gain {gain}");
        }
    }

    #[test]
    fn render_table_contains_all_series() {
        let f4 = fig4_series::<f32>(8192, 256, true);
        let table = render_table("Figure 4", &f4);
        assert!(table.contains("LU (MPI+CUDA)"));
        assert!(table.contains("Cholesky (MPI+ATLAS)"));
        assert!(table.lines().count() >= 7);
    }
}
