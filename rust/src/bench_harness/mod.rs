//! Figure-regeneration machinery (DESIGN.md §6).
//!
//! Two modes:
//!
//! * **real mode** — run the actual distributed solve in-process
//!   ([`crate::cluster::Cluster`]) and read the virtual-time makespan from
//!   the rank clocks.  Used at n ≤ ~2048 for validation and calibration.
//! * **model mode** ([`model`]) — evaluate the same per-algorithm cost
//!   structure analytically (op counts x engine cost model + message counts
//!   x network model), which reproduces the paper's n = 60000 figures
//!   without 28.8 GB of matrix.  [`calibrate`] quantifies model-vs-real
//!   agreement at small n (experiment E8).

pub mod calibrate;
pub mod figures;
pub mod model;

pub use figures::{fig3_series, fig4_series, FigurePoint, FigureSeries};
pub use model::{
    bicgstab_makespan_batched, chol_makespan_gpudirect, chol_makespan_prefetch,
    chol_makespan_resident, chol_solve_makespan_batched, chol_wire_stage, cg_makespan_batched,
    iter_makespan_fused, iter_makespan_gpudirect, iter_makespan_prefetch, iter_wire_stage,
    lu_makespan_gpudirect, lu_makespan_lookahead, lu_makespan_prefetch, lu_makespan_resident,
    chol_makespan_refined, iter_makespan_mixed, lu_makespan_refined, lu_solve_makespan_batched,
    lu_wire_stage, halo_wire, model_mixed_engaged, sparse_cg_split_makespan,
    sparse_iter_makespan_fused, sparse_iter_makespan_gpudirect, sparse_iter_makespan_halo,
    sparse_iter_makespan_mixed, sparse_iter_makespan_prefetch, sparse_iter_makespan_split,
    sparse_iter_wire_stage, sparse_pipecg_overlap_makespan, summa_makespan,
    summa_makespan_gpudirect, summa_makespan_prefetch, summa_makespan_resident, summa_wire_stage,
    trsm_makespan, trsv_resident_makespan, ModelParams, MODEL_REFINE_ITERS,
};

/// The paper's rank sweep (Figures 3 and 4).
pub const PAPER_RANKS: &[usize] = &[1, 2, 4, 8, 16];

/// The paper's fixed matrix order.
pub const PAPER_N: usize = 60_000;
