//! Experiment E8: model-vs-live calibration.
//!
//! The figure series at n = 60000 come from the analytic model
//! ([`super::model`]); this module runs the *live* distributed solver (real
//! messages, real tile ops, virtual clock) at small n and compares the two
//! makespans.  Agreement here is what licenses the model-mode figures.

use crate::accel::EngineKind;
use crate::cluster::{Cluster, ClusterConfig, Method};
use crate::comm::NetworkModel;
use crate::solvers::IterConfig;
use crate::workloads::Workload;
use crate::Result;

use super::figures;
use super::model::{method_makespan, ModelParams};

/// One calibration sample.
#[derive(Clone, Debug)]
pub struct CalibrationPoint {
    /// Problem size.
    pub n: usize,
    /// Ranks.
    pub ranks: usize,
    /// Live virtual-time makespan (real distributed run).
    pub live: f64,
    /// Analytic model makespan.
    pub model: f64,
}

impl CalibrationPoint {
    /// model / live ratio (1.0 = perfect).
    pub fn ratio(&self) -> f64 {
        self.model / self.live
    }
}

/// Run live-vs-model for `method` on the CPU arm across sizes and ranks.
pub fn calibrate(
    method: Method,
    workload: Workload,
    sizes: &[usize],
    ranks: &[usize],
    tile: usize,
) -> Result<Vec<CalibrationPoint>> {
    let mut out = Vec::new();
    for &n in sizes {
        for &p in ranks {
            let cfg = ClusterConfig {
                ranks: p,
                tile,
                engine: EngineKind::CpuSerial,
                net: NetworkModel::gigabit_ethernet(),
                iter: IterConfig { tol: 1e-10, max_iter: 400, restart: 30 },
                ..Default::default()
            };
            let cluster = Cluster::new(cfg)?;
            let report = cluster.solve::<f64>(workload, n, method)?;
            let iters = report.iter_stats.map(|(i, _, _)| i).unwrap_or(0);
            let params = ModelParams {
                tile,
                shape: crate::mesh::MeshShape::near_square(p),
                net: NetworkModel::gigabit_ethernet(),
                engine: crate::accel::ComputeProfile::q6600_atlas(),
                panel_cpu: crate::accel::ComputeProfile::q6600_atlas(),
                // The calibration workloads are diagonally dominant: partial
                // pivoting never interchanges, so the live runs send no swap
                // messages and the model must not charge any.
                swap_fraction: match workload {
                    Workload::DiagDominant | Workload::Spd | Workload::Poisson2d => 0.0,
                    Workload::Econometric => 0.0,
                },
                device_mem: crate::accel::DEFAULT_DEVICE_MEM,
            };
            // Iterative solvers run on the fused BLAS-1 kernels since the
            // residency PR, so the fused twin is the one that mirrors the
            // live charges (on the host arm residency itself is a no-op).
            let model = match method {
                Method::Iterative(m) => {
                    super::model::iter_makespan_fused::<f64>(m, n, iters, 30, &params)
                }
                _ => method_makespan::<f64>(method, n, iters, 30, &params),
            };
            out.push(CalibrationPoint { n, ranks: p, live: report.makespan(), model });
        }
    }
    Ok(out)
}

/// Render calibration rows.
pub fn render(points: &[CalibrationPoint]) -> String {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.n.to_string(),
                p.ranks.to_string(),
                crate::util::fmt::secs(p.live),
                crate::util::fmt::secs(p.model),
                format!("{:.2}", p.ratio()),
            ]
        })
        .collect();
    crate::util::fmt::table(&["n", "P", "live makespan", "model makespan", "model/live"], &rows)
}

/// Convenience used by the calibration bench: assert the model is within a
/// factor band of live runs (loose — the model is for figure *shape*).
pub fn max_ratio_error(points: &[CalibrationPoint]) -> f64 {
    points
        .iter()
        .map(|p| {
            let r = p.ratio();
            if r < 1.0 { 1.0 / r } else { r }
        })
        .fold(1.0, f64::max)
}

/// Keep figures linked in so model-mode users see both entry points.
pub use figures::render_table as _render_table;
