//! Analytic virtual-time model of the distributed algorithms.
//!
//! Mirrors, term by term, what the *implementation* does — same tile-op
//! sequence, same collectives — but evaluates counts instead of executing,
//! so the paper's n = 60000 runs fit in microseconds of bench time.  Every
//! per-op cost comes from the same [`ComputeProfile`]s and [`NetworkModel`]
//! the live virtual clock uses; `calibrate` checks the model against live
//! runs at small n.
//!
//! Conventions: `kt = ceil(n / tile)` tile steps; per-rank tile counts use
//! the balanced block-cyclic bounds `ceil(x / pr)` / `ceil(x / pc)`.

use crate::accel::engine::{spmv_cost, tile_op_cost};
use crate::accel::{ComputeProfile, OpClass};
use crate::comm::NetworkModel;
use crate::dist::ceil_div;
use crate::mesh::MeshShape;
use crate::solvers::IterMethod;
use crate::Scalar;

/// Everything the analytic model needs.
#[derive(Clone, Copy, Debug)]
pub struct ModelParams {
    /// Tile size.
    pub tile: usize,
    /// Mesh shape.
    pub shape: MeshShape,
    /// Network profile.
    pub net: NetworkModel,
    /// Tile-op profile (GTX 280 for the CUDA arm, Q6600 for ATLAS).
    pub engine: ComputeProfile,
    /// Panel-factorisation profile (always host CPU — the MAGMA-style split).
    pub panel_cpu: ComputeProfile,
    /// Expected fraction of LU elimination steps whose pivot row differs
    /// from the diagonal row (drives the row-swap message count): ~0.5+ for
    /// general matrices, ~0 for diagonally-dominant ones (no interchanges).
    pub swap_fraction: f64,
}

impl ModelParams {
    fn op<S: Scalar>(&self, name: &str) -> f64 {
        tile_op_cost::<S>(&self.engine, name, self.tile).total()
    }

    fn blas1<S: Scalar>(&self, len: usize) -> f64 {
        // BLAS-1 executes on the host in both arms (see XlaEngine::blas1_cost).
        self.panel_cpu
            .op_cost::<S>(OpClass::Blas1, 2 * len as u64, 3 * len * S::BYTES, 3 * len * S::BYTES)
            .total()
    }

    /// One point-to-point message of `elems` scalars.
    fn msg<S: Scalar>(&self, elems: usize) -> f64 {
        self.net.p2p_secs(elems * S::BYTES)
    }

    /// A binomial broadcast/reduce of `elems` scalars over `p` ranks
    /// (critical path: ceil(log2 p) rounds).
    fn tree<S: Scalar>(&self, p: usize, elems: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let rounds = usize::BITS - (p - 1).leading_zeros();
        rounds as f64 * self.msg::<S>(elems)
    }

    /// Ring allgather of per-rank blocks of `elems` scalars over `p` ranks.
    fn ring<S: Scalar>(&self, p: usize, elems: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        (p - 1) as f64 * self.msg::<S>(elems)
    }
}

/// Modelled makespan of the distributed block LU **factorisation + solve**.
pub fn lu_makespan<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let t2 = t * t;
    let mut total = 0.0;

    for k in 0..kt {
        let mk = kt - k; // panel tiles (incl. diagonal)
        let trailing = mk - 1;
        // 1. panel gather + scatter.  Gather: the (pr-1) senders stream
        //    their ~mk/pr tiles concurrently (each serialised on its own
        //    NIC); scatter: the owner streams all remote tiles back through
        //    its single NIC — the asymmetric bottleneck.
        let remote_tiles = mk - ceil_div(mk, pr); // tiles not already on the owner
        if pr > 1 {
            total += (ceil_div(mk, pr) + remote_tiles) as f64 * p.msg::<S>(t2);
        }
        // 2. host getrf of the (mk*t x t) real panel.
        let flops = (mk * t) as u64 * (t as u64) * (t as u64);
        total += p
            .panel_cpu
            .op_cost::<S>(OpClass::Blas3, flops, mk * t2 * S::BYTES, mk * t2 * S::BYTES)
            .total();
        // 3. pivot broadcast + row swaps.  A swap is a cross-row message
        //    pair only when the two rows live on different process rows
        //    (probability (pr-1)/pr); same-row swaps are local copies.
        total += p.tree::<S>(pr * pc, t);
        if pr > 1 && p.swap_fraction > 0.0 {
            let seg = ceil_div(kt, pc) * t; // row segment elems per rank
            let cross = (pr - 1) as f64 / pr as f64;
            total += p.swap_fraction * cross * t as f64 * p.msg::<S>(seg);
        }
        if trailing == 0 {
            continue;
        }
        // 4. L11 row broadcast + U12 trsm on the pivot row.
        total += p.tree::<S>(pc, t2);
        total += ceil_div(trailing, pc) as f64 * p.op::<S>("trsm_llu");
        // 5. panel broadcasts: L21 along rows, U12 along columns.
        total += ceil_div(trailing, pr) as f64 * p.tree::<S>(pc, t2);
        total += ceil_div(trailing, pc) as f64 * p.tree::<S>(pr, t2);
        // 6. trailing update per rank.
        let my_tiles = ceil_div(trailing, pr) * ceil_div(trailing, pc);
        total += my_tiles as f64 * p.op::<S>("gemm_update");
    }
    // Solve: two triangular substitutions.
    total += trsv_makespan::<S>(n, p) * 2.0;
    total
}

/// Modelled makespan of the distributed block Cholesky factorisation+solve.
pub fn chol_makespan<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let t2 = t * t;
    let mut total = 0.0;
    for k in 0..kt {
        let trailing = kt - k - 1;
        // potrf + column broadcast of L11.
        total += p.op::<S>("potrf");
        total += p.tree::<S>(pr, t2);
        // panel trsm_rlt on the column's ranks.
        total += ceil_div(trailing, pr) as f64 * p.op::<S>("trsm_rlt");
        if trailing == 0 {
            continue;
        }
        // row + column broadcasts of the panel.
        total += ceil_div(trailing, pr) as f64 * p.tree::<S>(pc, t2);
        total += ceil_div(trailing, pc) as f64 * p.tree::<S>(pr, t2);
        // trailing update, lower half only: ~half the tiles.
        let my_tiles = (ceil_div(trailing, pr) * ceil_div(trailing, pc)).div_ceil(2);
        total += my_tiles as f64 * p.op::<S>("gemm_nt_update");
    }
    // Forward solve + transpose redistribution + backward solve.
    total += trsv_makespan::<S>(n, p) * 2.0;
    let my_tiles = ceil_div(kt, p.shape.pr) * ceil_div(kt, p.shape.pc);
    total += my_tiles as f64 * p.msg::<S>(t2); // ptranspose traffic per rank
    total
}

/// Modelled makespan of one distributed triangular substitution.
pub fn trsv_makespan<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let mut total = 0.0;
    for k in 0..kt {
        let others = kt - k - 1;
        // diag trsv + world bcast of y(k).
        total += p.op::<S>("trsv_lu");
        total += p.tree::<S>(pr * pc, t);
        // column tiles broadcast along rows + local gemv_update per rank.
        let my_rows = ceil_div(others, pr);
        total += my_rows as f64 * (p.tree::<S>(pc, t * t) + p.op::<S>("gemv_update"));
    }
    total
}

/// Modelled makespan of `iters` iterations of an iterative method.
pub fn iter_makespan<S: Scalar>(
    method: IterMethod,
    n: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let my_rows = ceil_div(kt, pr);
    let my_cols = ceil_div(kt, pc);
    let vec_elems = my_rows * t;

    // One distributed matvec (pgemv): allgather + per-tile gemv/axpy + allreduce.
    let matvec = p.ring::<S>(pr, vec_elems)
        + (my_rows * my_cols) as f64 * (p.op::<S>("gemv") + p.blas1::<S>(t))
        + 2.0 * p.tree::<S>(pc, vec_elems);
    // Transposed matvec (pgemv_t): local + per-col reduce + row allgather.
    let matvec_t = (my_rows * my_cols) as f64 * (p.op::<S>("gemv_t") + p.blas1::<S>(t))
        + my_cols as f64 * p.tree::<S>(pr, t)
        + p.ring::<S>(pc, vec_elems);
    // A distributed dot: local blas1 + scalar allreduce over the column comm.
    let dot = my_rows as f64 * p.blas1::<S>(t) + 2.0 * p.tree::<S>(pr, 1);
    // A local vector op.
    let vop = my_rows as f64 * p.blas1::<S>(t);

    let per_iter = match method {
        IterMethod::Cg => matvec + 2.0 * dot + 3.0 * vop,
        IterMethod::Bicg => matvec + matvec_t + 3.0 * dot + 7.0 * vop,
        IterMethod::Bicgstab => 2.0 * matvec + 5.0 * dot + 6.0 * vop,
        IterMethod::Gmres => {
            // Average Arnoldi step at restart m: ~(m/2 + 1) dots and axpys.
            let m = restart.max(1) as f64;
            matvec + (m / 2.0 + 1.0) * (dot + vop) + 2.0 * vop
        }
    };
    iters as f64 * per_iter
}

/// Modelled makespan of `iters` iterations of a Krylov method over a
/// *sparse* row-block CSR operand with `nnz` stored entries.
///
/// Mirrors [`crate::pblas::pspmv()`] / [`crate::pblas::pspmv_t`] term by
/// term: a matvec is one column-comm ring allgather of the x blocks (the
/// halo-free row-block exchange — the model prices shipping the whole
/// vector, not a stencil halo) plus one local CSR matvec of `~nnz/pr`
/// entries at `2·nnz` flops ([`spmv_cost`]); there is **no** per-tile gemv
/// stream and no row allreduce, because rows are whole on their owners.
/// The transpose matvec is local plus a full-length column-comm allreduce.
pub fn sparse_iter_makespan<S: Scalar>(
    method: IterMethod,
    n: usize,
    nnz: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let pr = p.shape.pr;
    let my_rows = ceil_div(kt, pr);
    let vec_elems = my_rows * t;
    let full_elems = kt * t;
    let local_nnz = ceil_div(nnz, pr);

    // pspmv: column allgather of the x blocks + one local CSR matvec.
    let matvec = p.ring::<S>(pr, vec_elems)
        + spmv_cost::<S>(&p.engine, local_nnz, vec_elems, vec_elems).total();
    // pspmv_t: local transpose matvec (full-width output) + full-length
    // column allreduce.
    let matvec_t = spmv_cost::<S>(&p.engine, local_nnz, vec_elems, full_elems).total()
        + 2.0 * p.tree::<S>(pr, full_elems);
    // Dots and local vector ops are format-independent (same as dense).
    let dot = my_rows as f64 * p.blas1::<S>(t) + 2.0 * p.tree::<S>(pr, 1);
    let vop = my_rows as f64 * p.blas1::<S>(t);

    let per_iter = match method {
        IterMethod::Cg => matvec + 2.0 * dot + 3.0 * vop,
        IterMethod::Bicg => matvec + matvec_t + 3.0 * dot + 7.0 * vop,
        IterMethod::Bicgstab => 2.0 * matvec + 5.0 * dot + 6.0 * vop,
        IterMethod::Gmres => {
            let m = restart.max(1) as f64;
            matvec + (m / 2.0 + 1.0) * (dot + vop) + 2.0 * vop
        }
    };
    iters as f64 * per_iter
}

/// Modelled makespan for a (method, engine) arm.
pub fn method_makespan<S: Scalar>(
    method: crate::cluster::Method,
    n: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    match method {
        crate::cluster::Method::Lu => lu_makespan::<S>(n, p),
        crate::cluster::Method::Cholesky => chol_makespan::<S>(n, p),
        crate::cluster::Method::Iterative(m) => iter_makespan::<S>(m, n, iters, restart, p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(ranks: usize, gpu: bool) -> ModelParams {
        ModelParams {
            tile: 256,
            shape: MeshShape::near_square(ranks),
            net: NetworkModel::gigabit_ethernet(),
            engine: if gpu {
                ComputeProfile::gtx280_cublas()
            } else {
                ComputeProfile::q6600_atlas()
            },
            panel_cpu: ComputeProfile::q6600_atlas(),
            swap_fraction: 0.5,
        }
    }

    #[test]
    fn lu_scales_down_with_ranks() {
        let n = 8192;
        let t1 = lu_makespan::<f32>(n, &params(1, false));
        let t4 = lu_makespan::<f32>(n, &params(4, false));
        let t16 = lu_makespan::<f32>(n, &params(16, false));
        assert!(t4 < t1 && t16 < t4, "{t1} {t4} {t16}");
        // sub-linear (communication overhead)
        assert!(t1 / t16 < 16.0);
        assert!(t1 / t16 > 2.0);
    }

    #[test]
    fn gpu_arm_faster_but_not_dramatically() {
        // The paper's core observation at n = 60000.
        let n = 60_000;
        let cpu = lu_makespan::<f32>(n, &params(16, false));
        let gpu = lu_makespan::<f32>(n, &params(16, true));
        let ratio = cpu / gpu;
        assert!(ratio > 1.0, "CUDA arm must win: {ratio}");
        assert!(ratio < 30.0, "but transfers cap the gain: {ratio}");
    }

    #[test]
    fn iterative_scales() {
        let n = 16_384;
        let t1 = iter_makespan::<f32>(IterMethod::Bicgstab, n, 100, 30, &params(1, false));
        let t16 = iter_makespan::<f32>(IterMethod::Bicgstab, n, 100, 30, &params(16, false));
        assert!(t16 < t1);
        assert!(t1 / t16 < 16.0);
    }

    #[test]
    fn dp_slower_than_sp() {
        let n = 30_000;
        let sp = lu_makespan::<f32>(n, &params(8, true));
        let dp = lu_makespan::<f64>(n, &params(8, true));
        assert!(dp > sp, "{dp} vs {sp}");
    }

    #[test]
    fn trsv_minor_vs_factorisation() {
        let n = 30_000;
        let p = params(8, false);
        assert!(trsv_makespan::<f32>(n, &p) < 0.1 * lu_makespan::<f32>(n, &p));
    }

    #[test]
    fn sparse_cg_beats_dense_cg_by_orders_of_magnitude() {
        // A 1000x1000 grid: n = 1e6, nnz ~ 5e6 — the regime where the
        // sparse operand is the whole point of an iterative method.
        let g = 1_000usize;
        let n = g * g;
        let nnz = 5 * g * g - 4 * g;
        let sparse16 =
            sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &params(16, false));
        let dense16 = iter_makespan::<f64>(IterMethod::Cg, n, 100, 30, &params(16, false));
        assert!(
            sparse16 < dense16 / 100.0,
            "2·nnz flops must beat 2·n² by orders of magnitude: {sparse16} vs {dense16}"
        );
        // BiCG pays the extra transpose matvec + allreduce.
        let cg = sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &params(4, false));
        let bicg =
            sparse_iter_makespan::<f64>(IterMethod::Bicg, n, nnz, 100, 30, &params(4, false));
        assert!(bicg > cg);
    }

    #[test]
    fn sparse_scaling_is_compute_bound_only() {
        // Compute partitioning scales; but on Gigabit Ethernet the
        // halo-free full-vector allgather costs ~n bytes *regardless of
        // P*, so the network-inclusive makespan stops improving — the
        // honest flip side of the simple exchange (DESIGN.md §10).
        let g = 1_000usize;
        let (n, nnz) = (g * g, 5 * g * g - 4 * g);
        let ideal = |ranks: usize| ModelParams {
            net: NetworkModel::ideal(),
            ..params(ranks, false)
        };
        let t1 = sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &ideal(1));
        let t16 = sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &ideal(16));
        assert!(t16 < t1, "ideal network: more ranks must win ({t1} vs {t16})");
        assert!(t1 / t16 < 16.0, "sub-linear (replicated vector ops)");
        // And with the real network, the allgather term must actually cap
        // scaling: P=16 buys essentially nothing over P=4.
        let g4 = sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &params(4, false));
        let g16 = sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &params(16, false));
        assert!(
            g16 > 0.8 * g4,
            "gigabit: allgather (~n bytes regardless of P) must cap scaling: {g4} vs {g16}"
        );
    }
}
