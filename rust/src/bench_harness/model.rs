//! Analytic virtual-time model of the distributed algorithms.
//!
//! Mirrors, term by term, what the *implementation* does — same tile-op
//! sequence, same collectives — but evaluates counts instead of executing,
//! so the paper's n = 60000 runs fit in microseconds of bench time.  Every
//! per-op cost comes from the same [`ComputeProfile`]s and [`NetworkModel`]
//! the live virtual clock uses; `calibrate` checks the model against live
//! runs at small n.
//!
//! Conventions: `kt = ceil(n / tile)` tile steps; per-rank tile counts use
//! the balanced block-cyclic bounds `ceil(x / pr)` / `ceil(x / pc)`.

use crate::accel::engine::{spmv_cost, tile_op_cost};
use crate::accel::{ComputeProfile, OpClass};
use crate::comm::NetworkModel;
use crate::dist::ceil_div;
use crate::mesh::MeshShape;
use crate::solvers::IterMethod;
use crate::Scalar;

/// Everything the analytic model needs.
#[derive(Clone, Copy, Debug)]
pub struct ModelParams {
    /// Tile size.
    pub tile: usize,
    /// Mesh shape.
    pub shape: MeshShape,
    /// Network profile.
    pub net: NetworkModel,
    /// Tile-op profile (GTX 280 for the CUDA arm, Q6600 for ATLAS).
    pub engine: ComputeProfile,
    /// Panel-factorisation profile (always host CPU — the MAGMA-style split).
    pub panel_cpu: ComputeProfile,
    /// Expected fraction of LU elimination steps whose pivot row differs
    /// from the diagonal row (drives the row-swap message count): ~0.5+ for
    /// general matrices, ~0 for diagonally-dominant ones (no interchanges).
    pub swap_fraction: f64,
    /// Device-memory budget of the residency cache, bytes (GTX 280 = 1 GiB;
    /// only the `*_resident` / `*_fused` twins read it).
    pub device_mem: usize,
}

impl ModelParams {
    fn op<S: Scalar>(&self, name: &str) -> f64 {
        tile_op_cost::<S>(&self.engine, name, self.tile).total()
    }

    fn blas1<S: Scalar>(&self, len: usize) -> f64 {
        // BLAS-1 executes on the host in both arms (see XlaEngine::blas1_cost).
        self.panel_cpu
            .op_cost::<S>(OpClass::Blas1, 2 * len as u64, 3 * len * S::BYTES, 3 * len * S::BYTES)
            .total()
    }

    /// One point-to-point message of `elems` scalars.
    fn msg<S: Scalar>(&self, elems: usize) -> f64 {
        self.net.p2p_secs(elems * S::BYTES)
    }

    /// A binomial broadcast/reduce of `elems` scalars over `p` ranks
    /// (critical path: ceil(log2 p) rounds).
    fn tree<S: Scalar>(&self, p: usize, elems: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let rounds = usize::BITS - (p - 1).leading_zeros();
        rounds as f64 * self.msg::<S>(elems)
    }

    /// Ring allgather of per-rank blocks of `elems` scalars over `p` ranks.
    fn ring<S: Scalar>(&self, p: usize, elems: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        (p - 1) as f64 * self.msg::<S>(elems)
    }

    // ---- residency-aware legs (DESIGN.md §12) ----------------------------

    /// Tile-op cost with the PCIe stream share removed — what a call with
    /// all operands device-resident charges.
    fn op_resident<S: Scalar>(&self, name: &str) -> f64 {
        use crate::accel::engine::{op_flops, op_touched_elems};
        let (tin, tout) = op_touched_elems(name, self.tile);
        self.engine
            .op_cost::<S>(
                crate::accel::OpClass::of(name),
                op_flops(name, self.tile as u64),
                (tin + tout) * S::BYTES,
                0,
            )
            .total()
    }

    /// PCIe time for `elems` scalars (0 on host profiles).
    fn xfer<S: Scalar>(&self, elems: usize) -> f64 {
        if self.engine.pcie_bw > 0.0 {
            elems as f64 * S::BYTES as f64 / self.engine.pcie_bw
        } else {
            0.0
        }
    }

    /// Per-step PCIe extra of one resident trailing/accumulation sweep
    /// (the shared pricing of the LU/Cholesky/SUMMA residency twins):
    /// broadcast panels (`panel_copies` live sets of `my_rows + my_cols`
    /// tiles) stream H2D once per step; the C tiles pay their fill +
    /// write-back on the first step — or on every step once the working
    /// set thrashes past the device budget — and otherwise re-stream only
    /// the `invalidated` fraction; the total is clamped below the
    /// streaming flow's `clamp_calls`·t² per-tile share so a resident
    /// step can never price above a streaming one by construction.
    #[allow(clippy::too_many_arguments)]
    fn resident_extra<S: Scalar>(
        &self,
        my_rows: usize,
        my_cols: usize,
        my_tiles: usize,
        first_step: bool,
        invalidated: f64,
        clamp_calls: usize,
        panel_copies: usize,
    ) -> f64 {
        let t2 = self.tile * self.tile;
        let ws = (my_tiles + panel_copies * (my_rows + my_cols)) * t2 * S::BYTES;
        let c_factor = if ws > self.device_mem || first_step { 2.0 } else { invalidated };
        let extra = ((my_rows + my_cols) * t2) as f64 + c_factor * (my_tiles * t2) as f64;
        self.xfer::<S>(extra.min((clamp_calls * my_tiles * t2) as f64) as usize)
    }

    /// One RHS-panel tile op ([`crate::accel::panel_op_cost`]): k columns
    /// through one launch, the shared tile operand counted once.  At
    /// `k = 1` this prices exactly like [`ModelParams::op`] — the identity
    /// that pins the batched twins to their single-RHS baselines.
    fn panel_op<S: Scalar>(&self, name: &str, k: usize) -> f64 {
        crate::accel::panel_op_cost::<S>(&self.engine, name, self.tile, k).total()
    }

    /// One fused BLAS-1 kernel over a rank's whole local vector, mirroring
    /// [`crate::accel::Engine::blas1_fused_cost`]: one launch, `streams`
    /// vector-wide memory streams, dispatched to whichever arm is cheaper
    /// (tiny vectors stay host-side; big ones go to the device, where the
    /// model keeps — as a conservative bound on what the live cache
    /// charges — the full per-call PCIe streams).
    fn blas1_fused<S: Scalar>(&self, len: usize, streams: usize, flops_per_elem: u64) -> f64 {
        let bytes = streams * len * S::BYTES;
        let flops = flops_per_elem * len as u64;
        let own = self.engine.op_cost::<S>(OpClass::Blas1, flops, bytes, bytes).total();
        if self.engine.pcie_bw <= 0.0 {
            return own;
        }
        let host = self.panel_cpu.op_cost::<S>(OpClass::Blas1, flops, bytes, bytes).total();
        own.min(host)
    }
}

/// Per-step cost split of the block LU factorisation, mirroring the
/// lookahead implementation's phase boundaries:
///
/// * **panel CPU leg** — the host `getrf`: it runs on the diagonal owner's
///   *compute* timeline, so even the lookahead schedule keeps it on that
///   rank's critical path (the simulator has no second host thread);
/// * **panel comm legs** — gather/scatter messages, pivot broadcast and
///   the L21 row broadcasts: everything `factor_panel` puts on the wire,
///   i.e. the legs the lookahead schedule genuinely hides behind the
///   *previous* step's trailing update;
/// * **serial prefix** — row swaps, the U12 trsm row and the U12 column
///   broadcasts: work that stays on step `k`'s critical path;
/// * **trailing update** — the rank-T BLAS-3 stream that does the hiding.
///
/// Returned per step as `(panel_cpu, panel_comm, pre, trailing compute,
/// trailing PCIe)` — the trailing leg split so the residency twin can sum
/// the two shares (synchronous accounting) while the prefetch twin takes
/// their `max` (the copy-engine timeline rides under the gemm stream,
/// `DESIGN.md` §13).  The streaming flow folds everything into the compute
/// share (its per-call PCIe is inside the op price).
///
/// `resident` selects the device-residency pricing of the trailing leg
/// (`DESIGN.md` §12): each broadcast L21/U12 buffer streams H2D once per
/// step instead of once per GEMM, the trailing C tiles stay device-resident
/// across steps (pivot-row swaps invalidate their share, and a working set
/// beyond the device budget falls back to per-step thrash), and the
/// per-step extra is clamped to never exceed the streaming flow's.
fn lu_step_parts<S: Scalar>(
    n: usize,
    p: &ModelParams,
    resident: bool,
) -> Vec<(f64, f64, f64, f64, f64)> {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let t2 = t * t;
    let mut parts = Vec::with_capacity(kt);

    for k in 0..kt {
        let mk = kt - k; // panel tiles (incl. diagonal)
        let trailing = mk - 1;
        let mut panel_cpu = 0.0;
        let mut panel_comm = 0.0;
        let mut pre = 0.0;
        let mut update = 0.0;
        let mut update_pcie = 0.0;
        // 1. panel gather + scatter.  Gather: the (pr-1) senders stream
        //    their ~mk/pr tiles concurrently (each serialised on its own
        //    NIC); scatter: the owner streams all remote tiles back through
        //    its single NIC — the asymmetric bottleneck.
        let remote_tiles = mk - ceil_div(mk, pr); // tiles not already on the owner
        if pr > 1 {
            panel_comm += (ceil_div(mk, pr) + remote_tiles) as f64 * p.msg::<S>(t2);
        }
        // 2. host getrf of the (mk*t x t) real panel.
        let flops = (mk * t) as u64 * (t as u64) * (t as u64);
        panel_cpu += p
            .panel_cpu
            .op_cost::<S>(OpClass::Blas3, flops, mk * t2 * S::BYTES, mk * t2 * S::BYTES)
            .total();
        // 3. pivot broadcast + row swaps.  A swap is a cross-row message
        //    pair only when the two rows live on different process rows
        //    (probability (pr-1)/pr); same-row swaps are local copies.
        panel_comm += p.tree::<S>(pr * pc, t);
        if pr > 1 && p.swap_fraction > 0.0 {
            let seg = ceil_div(kt, pc) * t; // row segment elems per rank
            let cross = (pr - 1) as f64 / pr as f64;
            pre += p.swap_fraction * cross * t as f64 * p.msg::<S>(seg);
        }
        if trailing > 0 {
            // 4. L11 row broadcast + U12 trsm on the pivot row.
            pre += p.tree::<S>(pc, t2);
            pre += ceil_div(trailing, pc) as f64 * p.op::<S>("trsm_llu");
            // 5. panel broadcasts: L21 along rows (split-phase, part of the
            //    panel comm path) and U12 along columns (critical path).
            panel_comm += ceil_div(trailing, pr) as f64 * p.tree::<S>(pc, t2);
            pre += ceil_div(trailing, pc) as f64 * p.tree::<S>(pr, t2);
            // 6. trailing update per rank.
            let my_rows = ceil_div(trailing, pr);
            let my_cols = ceil_div(trailing, pc);
            let my_tiles = my_rows * my_cols;
            if resident && p.engine.pcie_bw > 0.0 {
                // Pivot swaps invalidate resident trailing tiles, hence
                // the swap_fraction re-stream share.
                update = my_tiles as f64 * p.op_resident::<S>("gemm_update");
                update_pcie = p.resident_extra::<S>(
                    my_rows,
                    my_cols,
                    my_tiles,
                    k == 0,
                    p.swap_fraction,
                    4,
                    1,
                );
            } else {
                update = my_tiles as f64 * p.op::<S>("gemm_update");
            }
        }
        parts.push((panel_cpu, panel_comm, pre, update, update_pcie));
    }
    parts
}

/// Fold the split trailing leg of [`lu_step_parts`] with `combine`
/// (`+` for the synchronous flows, `max` for the prefetch twin).
fn fold_update(
    parts: &[(f64, f64, f64, f64, f64)],
    combine: fn(f64, f64) -> f64,
) -> Vec<(f64, f64, f64, f64)> {
    parts
        .iter()
        .map(|&(cpu, comm, pre, uc, up)| (cpu, comm, pre, combine(uc, up)))
        .collect()
}

/// Modelled makespan of the distributed block LU **factorisation + solve**,
/// fully blocking schedule (every panel path serialised on the critical
/// path).
pub fn lu_makespan<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    let mut total = 0.0;
    for (panel_cpu, panel_comm, pre, update, update_pcie) in lu_step_parts::<S>(n, p, false) {
        total += panel_cpu + panel_comm + pre + update + update_pcie;
    }
    // Solve: two triangular substitutions.
    total += trsv_makespan::<S>(n, p) * 2.0;
    total
}

/// Modelled makespan of the same factorisation + solve under the **depth-1
/// lookahead** schedule ([`crate::solvers::direct::plu_factor`]): step
/// `k+1`'s panel *comm* legs ride under step `k`'s trailing update, so each
/// step pays `max(trailing, next panel comm)` instead of their sum.  The
/// host `getrf` is **not** hidden: it executes on the diagonal owner's
/// compute timeline ahead of that rank's trailing update, and the makespan
/// is the max over ranks — the simulator has no second host thread, so the
/// model keeps it serial too.  Always `<=` [`lu_makespan`]; strictly
/// smaller whenever there is a network (`P > 1`) to hide, and exactly
/// equal at `P = 1` — matching what the live simulator produces.
pub fn lu_makespan_lookahead<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    lu_lookahead_assembly(&fold_update(&lu_step_parts::<S>(n, p, false), |a, b| a + b))
        + trsv_makespan::<S>(n, p) * 2.0
}

/// Shared lookahead-schedule assembly over per-step parts.
fn lu_lookahead_assembly(parts: &[(f64, f64, f64, f64)]) -> f64 {
    let kt = parts.len();
    let mut total = parts[0].0 + parts[0].1; // panel 0 has nothing to hide behind
    for (k, &(_, _, pre, update)) in parts.iter().enumerate() {
        let (next_cpu, next_comm) =
            if k + 1 < kt { (parts[k + 1].0, parts[k + 1].1) } else { (0.0, 0.0) };
        total += pre + next_cpu + update.max(next_comm);
    }
    total
}

/// Residency twin of [`lu_makespan_lookahead`] (what `plu_factor` charges
/// with the [`crate::accel::TileCache`] active, `DESIGN.md` §12): the
/// trailing leg prices broadcast panels at one H2D per step and keeps the
/// trailing tiles device-resident (step 0 pays their fill + write-back
/// slots).  Always `<=` the streaming lookahead model — the per-step extra
/// is clamped below the streaming flow's — strictly smaller whenever there
/// is a PCIe link and real trailing work, and *exactly* equal on host
/// profiles (nothing streams there either way).
pub fn lu_makespan_resident<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    lu_lookahead_assembly(&fold_update(&lu_step_parts::<S>(n, p, true), |a, b| a + b))
        + trsv_makespan::<S>(n, p) * 2.0
}

/// Copy-engine twin of [`lu_makespan_resident`] (what `plu_factor` charges
/// with prefetch active, `DESIGN.md` §13): the trailing sweep's surviving
/// PCIe extra (broadcast-panel first touch, C fill / swap re-streams)
/// rides the copy-engine timeline under the gemm stream, so each step pays
/// `max(gemm, pcie)` instead of their sum.  `<=` the resident twin by
/// construction (`max <= +`), strictly smaller wherever residency still
/// paid PCIe on the compute path (accelerated arm with trailing work), and
/// exactly equal on host profiles (no PCIe either way).
pub fn lu_makespan_prefetch<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    lu_lookahead_assembly(&fold_update(&lu_step_parts::<S>(n, p, true), f64::max))
        + trsv_makespan::<S>(n, p) * 2.0
}

/// Does the LU copy-engine twin have strict headroom over the resident one
/// at this configuration — i.e. did residency leave PCIe **on the critical
/// path**?  The lookahead assembly already hides each step's trailing leg
/// behind the next panel's comm (`max(update, next_comm)`), so the copy
/// engine only wins where some step's resident trailing leg (gemm + PCIe
/// extra, both positive) actually exceeds that comm; at rank counts where
/// panel comm dominates every step, prefetch is an exact wash — which the
/// bench asserts rather than papering over.
pub fn lu_prefetch_headroom<S: Scalar>(n: usize, p: &ModelParams) -> bool {
    let parts = lu_step_parts::<S>(n, p, true);
    let kt = parts.len();
    (0..kt).any(|k| {
        let (_, _, _, uc, up) = parts[k];
        let next_comm = if k + 1 < kt { parts[k + 1].1 } else { 0.0 };
        uc > 0.0 && up > 0.0 && uc + up > next_comm
    })
}

/// Modelled makespan of SUMMA `C += A·B` over `n x n` operands: `kt` steps
/// of row+column panel broadcasts and a local rank-tile GEMM stream.
/// `overlapped` selects the double-buffered schedule
/// ([`crate::pblas::pgemm_acc`]): panel `kk+1` is on the wire while panel
/// `kk` multiplies, so each inner step pays `max(bcast, gemm)`.
pub fn summa_makespan<S: Scalar>(n: usize, p: &ModelParams, overlapped: bool) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let my_rows = ceil_div(kt, pr);
    let my_cols = ceil_div(kt, pc);
    let bcast = my_rows as f64 * p.tree::<S>(pc, t * t) + my_cols as f64 * p.tree::<S>(pr, t * t);
    let compute = (my_rows * my_cols) as f64 * (p.op::<S>("gemm") + p.blas1::<S>(t * t));
    if overlapped {
        bcast + (kt - 1) as f64 * bcast.max(compute) + compute
    } else {
        kt as f64 * (bcast + compute)
    }
}

/// Residency twin of [`summa_makespan`] (what `pgemm_acc` charges with the
/// tile cache active): the fused `gemm_acc` kernel replaces the
/// gemm-plus-host-axpy pair, the two panel buffers stream H2D once per
/// step (first touch) instead of once per tile GEMM, and the C tiles stay
/// device-resident across the `kt` steps — step 0 pays their fill +
/// write-back; a working set beyond the budget thrashes per step.
pub fn summa_makespan_resident<S: Scalar>(n: usize, p: &ModelParams, overlapped: bool) -> f64 {
    summa_makespan_cached::<S>(n, p, overlapped, |a, b| a + b)
}

/// Copy-engine twin of [`summa_makespan_resident`]: the per-step PCIe
/// extra (panel first touch, C fill on step 0) rides the copy-engine
/// timeline under the gemm stream, so each step's local leg pays
/// `max(gemm, pcie)` instead of their sum — `<=` the resident twin by
/// construction, strict wherever there is a PCIe link, exact on host
/// profiles.
pub fn summa_makespan_prefetch<S: Scalar>(n: usize, p: &ModelParams, overlapped: bool) -> f64 {
    summa_makespan_cached::<S>(n, p, overlapped, f64::max)
}

/// Shared residency-flow SUMMA assembly; `combine` folds the per-step
/// (gemm stream, PCIe extra) pair — `+` synchronous, `max` prefetch.
fn summa_makespan_cached<S: Scalar>(
    n: usize,
    p: &ModelParams,
    overlapped: bool,
    combine: fn(f64, f64) -> f64,
) -> f64 {
    let t = p.tile;
    let t2 = t * t;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let my_rows = ceil_div(kt, pr);
    let my_cols = ceil_div(kt, pc);
    let my_tiles = my_rows * my_cols;
    let bcast = my_rows as f64 * p.tree::<S>(pc, t2) + my_cols as f64 * p.tree::<S>(pr, t2);
    let gacc = my_tiles as f64 * p.op_resident::<S>("gemm_acc");
    // Double-buffered panels (2 sets in flight); nothing invalidates C;
    // the streaming gemm moves 3·t² per call (the axpy pass is host-side),
    // hence the clamp factor.
    let step_extra =
        |k: usize| -> f64 { p.resident_extra::<S>(my_rows, my_cols, my_tiles, k == 0, 0.0, 3, 2) };
    if overlapped {
        let mut total = bcast;
        for k in 0..kt {
            let compute = combine(gacc, step_extra(k));
            total += if k + 1 < kt { compute.max(bcast) } else { compute };
        }
        total
    } else {
        (0..kt).map(|k| bcast + combine(gacc, step_extra(k))).sum()
    }
}

/// Modelled makespan of the distributed block Cholesky factorisation+solve.
pub fn chol_makespan<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    chol_makespan_impl::<S>(n, p, false, |a, b| a + b)
}

/// Shared Cholesky assembly; `resident` selects the device-residency
/// pricing of the trailing leg (the other legs are identical in both
/// flows, which is what keeps the host arm an exact wash) and `combine`
/// folds its (gemm stream, PCIe extra) split — `+` synchronous, `max` for
/// the copy-engine prefetch twin.
fn chol_makespan_impl<S: Scalar>(
    n: usize,
    p: &ModelParams,
    resident: bool,
    combine: fn(f64, f64) -> f64,
) -> f64 {
    chol_factor_impl::<S>(n, p, resident, combine)
        + trsv_makespan::<S>(n, p) * 2.0
        + chol_transpose_traffic::<S>(n, p)
}

/// The Cholesky factorisation loop alone (no solve phase) — shared between
/// the per-vector flows and the batched-RHS twin, so `k = 1` batched prices
/// bit-identically to [`chol_makespan`].
fn chol_factor_impl<S: Scalar>(
    n: usize,
    p: &ModelParams,
    resident: bool,
    combine: fn(f64, f64) -> f64,
) -> f64 {
    let kt = ceil_div(n, p.tile);
    let mut total = 0.0;
    for k in 0..kt {
        // Term-level accumulation (NOT a per-step regroup): the committed
        // artifacts pin these bits, and `(x + a) + b != x + (a + b)`.
        total = chol_step_cost::<S>(n, p, k, resident, combine, total);
    }
    total
}

/// One panel step of the Cholesky factorisation loop, accumulated onto
/// `total` term by term — factored out of [`chol_factor_impl`] so the
/// fault-recovery twins can price a *replay span* (panels `[a, b)`) with
/// the identical per-step terms.  Threading the accumulator through keeps
/// the full-loop float association exactly what it was before the split.
fn chol_step_cost<S: Scalar>(
    n: usize,
    p: &ModelParams,
    k: usize,
    resident: bool,
    combine: fn(f64, f64) -> f64,
    mut total: f64,
) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let t2 = t * t;
    let trailing = kt - k - 1;
    // potrf + column broadcast of L11.
    total += p.op::<S>("potrf");
    total += p.tree::<S>(pr, t2);
    // panel trsm_rlt on the column's ranks.
    total += ceil_div(trailing, pr) as f64 * p.op::<S>("trsm_rlt");
    if trailing == 0 {
        return total;
    }
    // row + column broadcasts of the panel.
    total += ceil_div(trailing, pr) as f64 * p.tree::<S>(pc, t2);
    total += ceil_div(trailing, pc) as f64 * p.tree::<S>(pr, t2);
    // trailing update, lower half only: ~half the tiles.
    let my_rows = ceil_div(trailing, pr);
    let my_cols = ceil_div(trailing, pc);
    let my_tiles = (my_rows * my_cols).div_ceil(2);
    if resident && p.engine.pcie_bw > 0.0 {
        // No pivoting: nothing invalidates the resident trailing tiles.
        total += combine(
            my_tiles as f64 * p.op_resident::<S>("gemm_nt_update"),
            p.resident_extra::<S>(my_rows, my_cols, my_tiles, k == 0, 0.0, 4, 1),
        );
    } else {
        total += my_tiles as f64 * p.op::<S>("gemm_nt_update");
    }
    total
}

/// The `ptranspose` redistribution between the two Cholesky substitutions:
/// every owned tile crosses the network once (per-rank traffic).
fn chol_transpose_traffic<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let my_tiles = ceil_div(kt, p.shape.pr) * ceil_div(kt, p.shape.pc);
    my_tiles as f64 * p.msg::<S>(t * t)
}

/// Residency twin of [`chol_makespan`] (what `pchol_factor` charges with
/// the tile cache active): trailing `gemm_nt_update`s read once-streamed
/// broadcast panels and device-resident trailing tiles (no pivoting, so
/// nothing invalidates them); potrf/trsm panel legs keep their full
/// streaming price (they are O(kt) next to the O(kt·mt) trailing stream).
pub fn chol_makespan_resident<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    chol_makespan_impl::<S>(n, p, true, |a, b| a + b)
}

/// Copy-engine twin of [`chol_makespan_resident`]: the trailing sweep's
/// PCIe extra rides under the gemm_nt stream (`max` instead of `+`) —
/// `<=` the resident twin by construction, strict on the accelerated arm,
/// exact on host profiles.
pub fn chol_makespan_prefetch<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    chol_makespan_impl::<S>(n, p, true, f64::max)
}

/// Modelled makespan of one distributed triangular substitution.
pub fn trsv_makespan<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let mut total = 0.0;
    for k in 0..kt {
        let others = kt - k - 1;
        // diag trsv + world bcast of y(k).
        total += p.op::<S>("trsv_lu");
        total += p.tree::<S>(pr * pc, t);
        // column tiles broadcast along rows + local gemv_update per rank.
        let my_rows = ceil_div(others, pr);
        total += my_rows as f64 * (p.tree::<S>(pc, t * t) + p.op::<S>("gemv_update"));
    }
    total
}

/// [`trsv_makespan`] against **already-broadcast resident factors**: the
/// refinement sweeps of the refined direct flow re-substitute against the
/// exact L/U (or L/L^T) column tiles the initial narrow substitution pair
/// already broadcast along the rows, so only the per-step diagonal solve,
/// the solved-chunk world broadcast and the local `gemv_update`s recur —
/// the `my_rows * tree(pc, t^2)` factor-tile wire leg drops.  The
/// substitution-side analogue of the serving factor cache: the heavy part
/// of the operator is resident after the first pass, later passes pay
/// compute plus O(t)-payload control messages only.
pub fn trsv_resident_makespan<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let mut total = 0.0;
    for k in 0..kt {
        let others = kt - k - 1;
        total += p.op::<S>("trsv_lu");
        total += p.tree::<S>(pr * pc, t);
        let my_rows = ceil_div(others, pr);
        total += my_rows as f64 * p.op::<S>("gemv_update");
    }
    total
}

/// Modelled makespan of one RHS-panel triangular substitution
/// ([`crate::solvers::ptrsm`] with `k` right-hand sides): per panel step
/// one panel trsv (k columns, one launch, the diagonal tile counted once),
/// one world broadcast of the `k·t` solved panel chunk, and per owned
/// column tile **one** broadcast (amortized over all k columns — the term
/// a looped [`trsv_makespan`] pays k times) plus one panel `gemv_update`.
///
/// `trsm_makespan(n, 1, p) == trsv_makespan(n, p)` exactly (same terms,
/// and the panel ops price a one-column panel identically to the single
/// ops); for `k > 1` it is strictly below `k ×` the single-vector cost —
/// the tile broadcasts, launches and message latencies are paid once per
/// step, not once per vector.
pub fn trsm_makespan<S: Scalar>(n: usize, k: usize, p: &ModelParams) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let mut total = 0.0;
    for s in 0..kt {
        let others = kt - s - 1;
        // diag panel trsv + world bcast of the k-column chunk.
        total += p.panel_op::<S>("trsv_lu", k);
        total += p.tree::<S>(pr * pc, k * t);
        // column tiles broadcast once along rows + panel gemv per rank.
        let my_rows = ceil_div(others, pr);
        total += my_rows as f64 * (p.tree::<S>(pc, t * t) + p.panel_op::<S>("gemv_update", k));
    }
    total
}

/// Modelled makespan of a batched LU solve
/// ([`crate::solvers::plu_solve_panel`]): the factorisation is paid
/// **once** for the whole batch, then two RHS-panel substitutions.
/// `k = 1` reproduces [`lu_makespan`] bit for bit; `k > 1` is strictly
/// below `k ×` single (the factorisation amortizes outright and the
/// substitutions batch).
pub fn lu_solve_makespan_batched<S: Scalar>(n: usize, k: usize, p: &ModelParams) -> f64 {
    let mut total = 0.0;
    for (panel_cpu, panel_comm, pre, update, update_pcie) in lu_step_parts::<S>(n, p, false) {
        total += panel_cpu + panel_comm + pre + update + update_pcie;
    }
    total + trsm_makespan::<S>(n, k, p) * 2.0
}

/// Modelled makespan of a batched Cholesky solve
/// ([`crate::solvers::pchol_solve_panel`]): one factorisation, **one**
/// transpose redistribution (the looped flow pays it per vector), two
/// RHS-panel substitutions.  `k = 1` reproduces [`chol_makespan`] bit for
/// bit.
pub fn chol_solve_makespan_batched<S: Scalar>(n: usize, k: usize, p: &ModelParams) -> f64 {
    chol_factor_impl::<S>(n, p, false, |a, b| a + b)
        + trsm_makespan::<S>(n, k, p) * 2.0
        + chol_transpose_traffic::<S>(n, p)
}

/// Modelled makespan of `iters` blocked-CG iterations over `k` right-hand
/// sides ([`crate::solvers::block_cg`]): the matvec's allgather/allreduce
/// carry all k columns in one collective (one tree latency for the batch),
/// each owned `A` tile feeds one panel `gemv_acc` (streamed once, one
/// launch), the two dots ride a single k-lane allreduce, and the three
/// vector recurrences run one pass over `k·t`-wide blocks.  `k = 1`
/// reproduces the [`iter_makespan`] CG arm bit for bit; `k > 1` is
/// strictly below `k ×` single (shared tiles, launches and latencies).
pub fn cg_makespan_batched<S: Scalar>(n: usize, k: usize, iters: usize, p: &ModelParams) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let my_rows = ceil_div(kt, pr);
    let my_cols = ceil_div(kt, pc);
    let vec_elems = my_rows * t;

    // Shared matvec: one k-column allgather, one panel gemv_acc per owned
    // tile, one k-column allreduce.
    let matvec = p.ring::<S>(pr, k * vec_elems)
        + (my_rows * my_cols) as f64 * p.panel_op::<S>("gemv_acc", k)
        + 2.0 * p.tree::<S>(pc, k * vec_elems);
    // k-lane dot: per-column local partials (unchanged), one k-lane tree.
    let dot = k as f64 * (my_rows as f64 * p.blas1::<S>(t)) + 2.0 * p.tree::<S>(pr, k);
    // Column-batched vector op: one pass over the k-wide block row.
    let vop = my_rows as f64 * p.blas1::<S>(k * t);
    iters as f64 * (matvec + 2.0 * dot + 3.0 * vop)
}

/// Modelled makespan of `iters` blocked-BiCGSTAB iterations over `k`
/// right-hand sides ([`crate::solvers::block_bicgstab`]): the same
/// column-batched legs as [`cg_makespan_batched`] — k-column collectives,
/// panel `gemv_acc` per owned tile, k-lane dot reductions, `k·t`-wide
/// vector passes — assembled with the BiCGSTAB iteration shape (two
/// matvecs, five dots, six vector ops).  `k = 1` reproduces the
/// [`iter_makespan`] BiCGSTAB arm bit for bit; `k > 1` is strictly below
/// `k ×` single (shared tiles, launches and latencies).
pub fn bicgstab_makespan_batched<S: Scalar>(
    n: usize,
    k: usize,
    iters: usize,
    p: &ModelParams,
) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let my_rows = ceil_div(kt, pr);
    let my_cols = ceil_div(kt, pc);
    let vec_elems = my_rows * t;

    let matvec = p.ring::<S>(pr, k * vec_elems)
        + (my_rows * my_cols) as f64 * p.panel_op::<S>("gemv_acc", k)
        + 2.0 * p.tree::<S>(pc, k * vec_elems);
    let dot = k as f64 * (my_rows as f64 * p.blas1::<S>(t)) + 2.0 * p.tree::<S>(pr, k);
    let vop = my_rows as f64 * p.blas1::<S>(k * t);
    iters as f64 * (2.0 * matvec + 5.0 * dot + 6.0 * vop)
}

/// Modelled makespan of `iters` iterations of an iterative method.
pub fn iter_makespan<S: Scalar>(
    method: IterMethod,
    n: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let my_rows = ceil_div(kt, pr);
    let my_cols = ceil_div(kt, pc);
    let vec_elems = my_rows * t;

    // One distributed matvec (pgemv): allgather + per-tile fused gemv_acc
    // (the partial-sum accumulation lives in the kernel — no host axpy
    // pass) + allreduce.
    let matvec = p.ring::<S>(pr, vec_elems)
        + (my_rows * my_cols) as f64 * p.op::<S>("gemv_acc")
        + 2.0 * p.tree::<S>(pc, vec_elems);
    // Transposed matvec (pgemv_t): local gemv_t_acc + per-col reduce + row
    // allgather.
    let matvec_t = (my_rows * my_cols) as f64 * p.op::<S>("gemv_t_acc")
        + my_cols as f64 * p.tree::<S>(pr, t)
        + p.ring::<S>(pc, vec_elems);
    // A distributed dot: local blas1 + scalar allreduce over the column comm.
    let dot = my_rows as f64 * p.blas1::<S>(t) + 2.0 * p.tree::<S>(pr, 1);
    // A local vector op.
    let vop = my_rows as f64 * p.blas1::<S>(t);

    let per_iter = match method {
        IterMethod::Cg => matvec + 2.0 * dot + 3.0 * vop,
        // Pipelined CG, *blocking* schedule: one fused two-lane reduction
        // (2·tree latency), two local dot partials and nine vector
        // recurrences per iteration.  The overlapped schedule runs the
        // reduction under the matvec — see `pipecg_iter_makespan`.
        IterMethod::PipeCg => matvec + 2.0 * p.tree::<S>(p.shape.pr, 2) + 11.0 * vop,
        IterMethod::Bicg => matvec + matvec_t + 3.0 * dot + 7.0 * vop,
        IterMethod::Bicgstab => 2.0 * matvec + 5.0 * dot + 6.0 * vop,
        IterMethod::Gmres => {
            // Average Arnoldi step at restart m: ~(m/2 + 1) dots and axpys.
            let m = restart.max(1) as f64;
            matvec + (m / 2.0 + 1.0) * (dot + vop) + 2.0 * vop
        }
    };
    iters as f64 * per_iter
}

/// Fused + residency twin of [`iter_makespan`] for the solvers that run on
/// the fused BLAS-1 kernels (CG, pipelined CG, BiCGSTAB — `DESIGN.md`
/// §12); other methods fall back to the streaming model.  Mirrors the live
/// code: the dense matvec's A tiles stream H2D only while they fit the
/// device budget (first iteration; thereafter resident — the Ioannidis
/// keep-the-matrix-on-the-GPU win), per matvec only the x blocks (first
/// touch per tile column) and the device-resident partial result's single
/// write-back cross PCIe, and each fused vector kernel is one launch + one
/// pass charged at the arm's own profile with its full per-call streams (a
/// conservative bound; the live cache also elides most vector streams).
pub fn iter_makespan_fused<S: Scalar>(
    method: IterMethod,
    n: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    iter_makespan_cached::<S>(method, n, iters, restart, p, |a, b| a + b)
}

/// Copy-engine twin of [`iter_makespan_fused`] (`DESIGN.md` §13): the
/// matvec's surviving PCIe (x first touch + y write-back when A is
/// resident; the full per-call stream when the budget thrashes — exactly
/// the "budget forced eviction" case, where the live depth-1 prefetch
/// hides the re-streams under the gemv sweep) rides the copy-engine
/// timeline, so each matvec pays `max(gemv stream, PCIe)` instead of their
/// sum.  `<=` the resident twin by construction, strict on the accelerated
/// arm, exact on host profiles.
pub fn iter_makespan_prefetch<S: Scalar>(
    method: IterMethod,
    n: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    iter_makespan_cached::<S>(method, n, iters, restart, p, f64::max)
}

/// Dense matvec legs under the residency flow: `(gemv compute stream,
/// per-matvec PCIe, one-time A load)`.  With A resident (budget fits) the
/// PCIe share is the x blocks' first touch (`my_cols` blocks) plus the
/// partial result's one write-back per block (`my_rows` blocks); past the
/// budget every call re-streams its full footprint — the thrash the
/// prefetch twin hides and the synchronous twin pays on the compute path.
fn dense_matvec_terms<S: Scalar>(p: &ModelParams, n: usize) -> (f64, f64, f64) {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let my_rows = ceil_div(kt, p.shape.pr);
    let my_cols = ceil_div(kt, p.shape.pc);
    let my_tiles = my_rows * my_cols;
    let a_fits = my_tiles * t * t * S::BYTES <= p.device_mem;
    if p.engine.pcie_bw <= 0.0 {
        return (my_tiles as f64 * p.op::<S>("gemv_acc"), 0.0, 0.0);
    }
    let compute = my_tiles as f64 * p.op_resident::<S>("gemv_acc");
    if a_fits {
        (compute, p.xfer::<S>((my_cols + my_rows) * t), p.xfer::<S>(my_tiles * t * t))
    } else {
        // Thrash: per call A tile + x + y read + y write, like streaming.
        (compute, my_tiles as f64 * p.xfer::<S>(t * t + 3 * t), 0.0)
    }
}

/// Shared residency-flow assembly of the fused iterative twins; `combine`
/// folds the matvec's (compute, PCIe) split — `+` synchronous, `max`
/// prefetch.
fn iter_makespan_cached<S: Scalar>(
    method: IterMethod,
    n: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
    combine: fn(f64, f64) -> f64,
) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let my_rows = ceil_div(kt, pr);
    let vec_elems = my_rows * t;

    let (gemv_stream, matvec_pcie, a_load) = dense_matvec_terms::<S>(p, n);
    let matvec = p.ring::<S>(pr, vec_elems)
        + combine(gemv_stream, matvec_pcie)
        + 2.0 * p.tree::<S>(pc, vec_elems);
    // Unfused legs (host-side, as in the live code).
    let dot = my_rows as f64 * p.blas1::<S>(t) + 2.0 * p.tree::<S>(pr, 1);
    let vop = my_rows as f64 * p.blas1::<S>(t);
    // Fused kernels over the whole local replica: streams = operand vector
    // passes, flops/elem from the fused arithmetic.
    let axpy_norm2 = p.blas1_fused::<S>(vec_elems, 3, 4) + 2.0 * p.tree::<S>(pr, 1);
    let axpy_norm2_dot = p.blas1_fused::<S>(vec_elems, 4, 6) + 2.0 * p.tree::<S>(pr, 2);
    let norm2_dot = p.blas1_fused::<S>(vec_elems, 2, 4) + 2.0 * p.tree::<S>(pr, 2);
    let xpay = p.blas1_fused::<S>(vec_elems, 3, 2);

    if iters == 0 {
        return 0.0;
    }
    let per_iter = match method {
        // cg(): apply, p·Ap dot, x axpy, fused r update + ||r||², xpay.
        IterMethod::Cg => matvec + dot + vop + axpy_norm2 + xpay,
        // pipecg(): fused (γ,δ) partials + one two-lane reduction riding
        // with the matvec (blocking assembly here, like the baseline),
        // three xpay recurrences, three axpys.
        IterMethod::PipeCg => {
            matvec
                + p.blas1_fused::<S>(vec_elems, 2, 4)
                + 2.0 * p.tree::<S>(pr, 2)
                + 3.0 * xpay
                + 3.0 * vop
        }
        // bicgstab(): two matvecs; r0·v dot; fused s update + ||s||²;
        // fused (t·t, t·s); two x axpys; fused r update + ||r||² + r0·r;
        // p axpy + xpay.
        IterMethod::Bicgstab => {
            2.0 * matvec + dot + axpy_norm2 + norm2_dot + 3.0 * vop + axpy_norm2_dot + xpay
        }
        _ => return iter_makespan::<S>(method, n, iters, restart, p),
    };
    iters as f64 * per_iter + a_load
}

/// Modelled makespan of `iters` iterations of a Krylov method over a
/// *sparse* row-block CSR operand with `nnz` stored entries.
///
/// Mirrors [`crate::pblas::pspmv()`] / [`crate::pblas::pspmv_t`] term by
/// term: a matvec is one column-comm ring allgather of the x blocks (the
/// halo-free row-block exchange — the model prices shipping the whole
/// vector, not a stencil halo) plus one local CSR matvec of `~nnz/pr`
/// entries at `2·nnz` flops ([`spmv_cost`]); there is **no** per-tile gemv
/// stream and no row allreduce, because rows are whole on their owners.
/// The transpose matvec is local plus a full-length column-comm allreduce.
pub fn sparse_iter_makespan<S: Scalar>(
    method: IterMethod,
    n: usize,
    nnz: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let pr = p.shape.pr;
    let my_rows = ceil_div(kt, pr);
    let vec_elems = my_rows * t;
    let full_elems = kt * t;
    let local_nnz = ceil_div(nnz, pr);

    // pspmv: column allgather of the x blocks + one local CSR matvec.
    // The legs come from `sparse_cg_terms`, shared with the overlapped
    // variants — the overlap-never-loses asserts depend on both sides
    // pricing identical legs.
    let (ring, spmv, dot, vop) = sparse_cg_terms::<S>(n, nnz, p);
    let matvec = ring + spmv;
    // pspmv_t: local transpose matvec (full-width output) + full-length
    // column allreduce.
    let matvec_t = spmv_cost::<S>(&p.engine, local_nnz, vec_elems, full_elems).total()
        + 2.0 * p.tree::<S>(pr, full_elems);

    let per_iter = match method {
        IterMethod::Cg => matvec + 2.0 * dot + 3.0 * vop,
        IterMethod::PipeCg => matvec + 2.0 * p.tree::<S>(pr, 2) + 11.0 * vop,
        IterMethod::Bicg => matvec + matvec_t + 3.0 * dot + 7.0 * vop,
        IterMethod::Bicgstab => 2.0 * matvec + 5.0 * dot + 6.0 * vop,
        IterMethod::Gmres => {
            let m = restart.max(1) as f64;
            matvec + (m / 2.0 + 1.0) * (dot + vop) + 2.0 * vop
        }
    };
    iters as f64 * per_iter
}

/// Fused twin of [`sparse_iter_makespan`] for the fused-kernel solvers:
/// sparse operands run on the host arm (no AOT sparse kernel), so there is
/// no PCIe to save — the win is purely the collapsed launch count and
/// memory passes of the fused BLAS-1 chain, which is exactly what the
/// latency-bound small-`n` regime feels.
pub fn sparse_iter_makespan_fused<S: Scalar>(
    method: IterMethod,
    n: usize,
    nnz: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let pr = p.shape.pr;
    let my_rows = ceil_div(kt, pr);
    let vec_elems = my_rows * t;
    let (ring, spmv, dot, vop) = sparse_cg_terms::<S>(n, nnz, p);
    let matvec = ring + spmv;
    let axpy_norm2 = p.blas1_fused::<S>(vec_elems, 3, 4) + 2.0 * p.tree::<S>(pr, 1);
    let axpy_norm2_dot = p.blas1_fused::<S>(vec_elems, 4, 6) + 2.0 * p.tree::<S>(pr, 2);
    let norm2_dot = p.blas1_fused::<S>(vec_elems, 2, 4) + 2.0 * p.tree::<S>(pr, 2);
    let xpay = p.blas1_fused::<S>(vec_elems, 3, 2);
    let per_iter = match method {
        IterMethod::Cg => matvec + dot + vop + axpy_norm2 + xpay,
        IterMethod::PipeCg => {
            matvec
                + p.blas1_fused::<S>(vec_elems, 2, 4)
                + 2.0 * p.tree::<S>(pr, 2)
                + 3.0 * xpay
                + 3.0 * vop
        }
        IterMethod::Bicgstab => {
            2.0 * matvec + dot + axpy_norm2 + norm2_dot + 3.0 * vop + axpy_norm2_dot + xpay
        }
        _ => return sparse_iter_makespan::<S>(method, n, nnz, iters, restart, p),
    };
    iters as f64 * per_iter
}

/// Copy-engine twin of [`sparse_iter_makespan_fused`] — **identical by
/// definition**: sparse operands run on the host arm (no AOT sparse
/// kernel), nothing crosses PCIe, so the copy engine sits idle and
/// prefetch can neither win nor lose.  Exists so every bench row has all
/// three flows.
pub fn sparse_iter_makespan_prefetch<S: Scalar>(
    method: IterMethod,
    n: usize,
    nnz: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    sparse_iter_makespan_fused::<S>(method, n, nnz, iters, restart, p)
}

// ---- GPUDirect wire twins (DESIGN.md §16) ------------------------------
//
// The host-staged send path serialises a D2H copy ahead of every send of a
// device-dirty payload (`Ctx::host_read` flushes before the NIC sees the
// buffer).  The base models above never priced that leg — their comm terms
// assume the payload is already host-resident — so each kernel gets a
// `*_wire_stage` term (the staging PCIe the host-staged arm adds on the
// critical path) and a `*_makespan_gpudirect` twin (the prefetch twin plus
// whatever survives of the staging leg under the joint-occupancy wire,
// where the PCIe leg rides under the send's own NIC occupancy —
// [`crate::comm::VClock::wire_occupy_from`]).  `gpudirect <= prefetch +
// stage` holds by construction (`max(0, xfer - msg) <= xfer`), strictly
// wherever any device-dirty payload actually hits the wire (`stage > 0`,
// since a send's NIC leg is never free), and both terms vanish on host
// profiles — the exact wash the A/B bench pins.

/// One device-dirty wire payload of `elems` scalars: `(stage, residual)` —
/// the D2H leg the host-staged flow serialises ahead of the send, and what
/// survives of it under the GPUDirect joint-occupancy wire (the PCIe leg
/// extends the send only past the NIC leg it rides under).  `(0, 0)` on
/// host profiles.
fn wire_payload<S: Scalar>(p: &ModelParams, elems: usize) -> (f64, f64) {
    let stage = p.xfer::<S>(elems);
    if stage <= 0.0 {
        return (0.0, 0.0);
    }
    (stage, (stage - p.msg::<S>(elems)).max(0.0))
}

/// Per-step (stage, residual) sums of the LU device-dirty wire payloads:
/// the U12 column broadcasts (trailing tiles are device-dirty from the
/// previous trailing update) and, from step 1 on, the panel-gather legs of
/// the non-owner column ranks (their tiles went device-dirty in step
/// `k-1`'s update; step 0 gathers host-fresh tiles).  The L11 row
/// broadcast and SUMMA-style L21 legs stay host-clean (factored on the
/// host CPU), hence absent.
fn lu_wire_legs<S: Scalar>(n: usize, p: &ModelParams) -> (f64, f64) {
    let t2 = p.tile * p.tile;
    let kt = ceil_div(n, p.tile);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let (s1, r1) = wire_payload::<S>(p, t2);
    let (mut stage, mut residual) = (0.0, 0.0);
    for k in 0..kt {
        let mk = kt - k;
        let trailing = mk - 1;
        if pr > 1 {
            if k >= 1 {
                let remote_tiles = (mk - ceil_div(mk, pr)) as f64;
                stage += remote_tiles * s1;
                residual += remote_tiles * r1;
            }
            stage += ceil_div(trailing, pc) as f64 * s1;
            residual += ceil_div(trailing, pc) as f64 * r1;
        }
    }
    (stage, residual)
}

/// D2H staging PCIe the host-staged send path adds to the LU critical path
/// (0 on host profiles or at `pr = 1` — no column sends).
pub fn lu_wire_stage<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    lu_wire_legs::<S>(n, p).0
}

/// GPUDirect twin of [`lu_makespan_prefetch`]: device-dirty send payloads
/// go straight to the NIC, so of each staging leg only the excess over the
/// send's own NIC occupancy survives.  `<= lu_makespan_prefetch +
/// lu_wire_stage` by construction, strict wherever the stage term is
/// positive, exact wash on host profiles.
pub fn lu_makespan_gpudirect<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    lu_makespan_prefetch::<S>(n, p) + lu_wire_legs::<S>(n, p).1
}

/// Per-step (stage, residual) sums of the Cholesky device-dirty wire
/// payloads: the L11 column broadcast and the panel row broadcasts (both
/// read tiles the previous trailing update left device-dirty).
fn chol_wire_legs<S: Scalar>(n: usize, p: &ModelParams) -> (f64, f64) {
    let t2 = p.tile * p.tile;
    let kt = ceil_div(n, p.tile);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let (s1, r1) = wire_payload::<S>(p, t2);
    let (mut stage, mut residual) = (0.0, 0.0);
    for k in 0..kt {
        let trailing = kt - k - 1;
        if pr > 1 {
            stage += s1;
            residual += r1;
        }
        if pc > 1 {
            stage += ceil_div(trailing, pr) as f64 * s1;
            residual += ceil_div(trailing, pr) as f64 * r1;
        }
    }
    (stage, residual)
}

/// D2H staging PCIe the host-staged send path adds to the Cholesky
/// critical path (0 on host profiles or at `P = 1`).
pub fn chol_wire_stage<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    chol_wire_legs::<S>(n, p).0
}

/// GPUDirect twin of [`chol_makespan_prefetch`] — same construction as
/// [`lu_makespan_gpudirect`].
pub fn chol_makespan_gpudirect<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    chol_makespan_prefetch::<S>(n, p) + chol_wire_legs::<S>(n, p).1
}

/// D2H staging PCIe the host-staged send path adds to SUMMA: **zero** —
/// the broadcast A/B panels are read-only inputs, host-clean by
/// construction, so `wire_read` routes them through the host path either
/// way and GPUDirect is an exact wash here (which the bench asserts rather
/// than papering over).
pub fn summa_wire_stage<S: Scalar>(_n: usize, _p: &ModelParams) -> f64 {
    0.0
}

/// GPUDirect twin of [`summa_makespan_prefetch`] — identical by
/// definition: no device-dirty payload ever hits SUMMA's wire.
pub fn summa_makespan_gpudirect<S: Scalar>(n: usize, p: &ModelParams, overlapped: bool) -> f64 {
    summa_makespan_prefetch::<S>(n, p, overlapped)
}

/// Per-iteration (stage, residual) sums of the dense Krylov device-dirty
/// wire payloads: the matvec's partial-result allreduce (`y_part`
/// accumulates on the device under the fused `gemv_acc` sweep, so its
/// reduction payload is device-dirty) — once per matvec, twice per
/// BiCGSTAB iteration.  The x-block allgather ships host-written vectors
/// (host-clean), hence absent.
fn iter_wire_legs<S: Scalar>(method: IterMethod, n: usize, iters: usize, p: &ModelParams) -> (f64, f64) {
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    if pc <= 1 {
        return (0.0, 0.0);
    }
    let vec_elems = ceil_div(ceil_div(n, p.tile), pr) * p.tile;
    let (s1, r1) = wire_payload::<S>(p, vec_elems);
    let matvecs = match method {
        IterMethod::Cg | IterMethod::PipeCg => 1.0,
        IterMethod::Bicgstab => 2.0,
        // Methods outside the fused flow keep the host-staged accounting.
        _ => return (0.0, 0.0),
    };
    let per = iters as f64 * matvecs;
    (per * s1, per * r1)
}

/// D2H staging PCIe the host-staged send path adds to the dense Krylov
/// critical path (0 on host profiles or at `pc = 1` — the row allreduce
/// degenerates and nothing is sent).
pub fn iter_wire_stage<S: Scalar>(method: IterMethod, n: usize, iters: usize, p: &ModelParams) -> f64 {
    iter_wire_legs::<S>(method, n, iters, p).0
}

/// GPUDirect twin of [`iter_makespan_prefetch`] — same construction as
/// [`lu_makespan_gpudirect`].
pub fn iter_makespan_gpudirect<S: Scalar>(
    method: IterMethod,
    n: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    iter_makespan_prefetch::<S>(method, n, iters, restart, p)
        + iter_wire_legs::<S>(method, n, iters, p).1
}

/// D2H staging PCIe of the sparse halo exchange: **zero** — sparse
/// operands run on the host arm (no AOT sparse kernel), every ghost
/// segment is host-clean, and the halo wire composes with GPUDirect as an
/// exact wash.
pub fn sparse_iter_wire_stage<S: Scalar>(_n: usize, _nnz: usize, _p: &ModelParams) -> f64 {
    0.0
}

/// GPUDirect twin of [`sparse_iter_makespan_prefetch`] — identical by
/// definition (host-clean ghost payloads; the wire routing changes
/// nothing).
pub fn sparse_iter_makespan_gpudirect<S: Scalar>(
    method: IterMethod,
    n: usize,
    nnz: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    sparse_iter_makespan_prefetch::<S>(method, n, nnz, iters, restart, p)
}

/// Modelled makespan of `iters` sparse CG iterations under the
/// **split-phase** `pspmv` schedule ([`crate::pblas::pspmv()`]): the x
/// allgather is started, the diagonal-block rows (fraction `diag_frac` of
/// the stored entries — close to 1 for banded stencils, whose bandwidth is
/// far below a row block) compute while it flies, and the off-block rows
/// finish on completion.  Per matvec the model pays
/// `max(ring, diag) + off` instead of `ring + diag + off`; dots and vector
/// recurrences are unchanged from [`sparse_iter_makespan`]'s CG arm, which
/// is the blocking baseline.
pub fn sparse_cg_split_makespan<S: Scalar>(
    n: usize,
    nnz: usize,
    iters: usize,
    diag_frac: f64,
    p: &ModelParams,
) -> f64 {
    let (ring, spmv, dot, vop) = sparse_cg_terms::<S>(n, nnz, p);
    let matvec = ring.max(diag_frac * spmv) + (1.0 - diag_frac) * spmv;
    iters as f64 * (matvec + 2.0 * dot + 3.0 * vop)
}

/// Modelled makespan of `iters` **pipelined** sparse CG iterations with
/// both overlaps active ([`crate::solvers::iterative::pipecg()`] over
/// split-phase `pspmv`): the fused two-lane reduction rides under the
/// matvec, whose allgather in turn rides under the diagonal-block pass.
/// The blocking baseline is [`sparse_iter_makespan`] with
/// [`IterMethod::PipeCg`].
pub fn sparse_pipecg_overlap_makespan<S: Scalar>(
    n: usize,
    nnz: usize,
    iters: usize,
    diag_frac: f64,
    p: &ModelParams,
) -> f64 {
    let (ring, spmv, _dot, vop) = sparse_cg_terms::<S>(n, nnz, p);
    let matvec = ring.max(diag_frac * spmv) + (1.0 - diag_frac) * spmv;
    let reduction = 2.0 * p.tree::<S>(p.shape.pr, 2);
    iters as f64 * (matvec.max(reduction) + 11.0 * vop)
}

/// Wire leg of one halo exchange ([`crate::pblas::pspmv_halo`]): the
/// makespan rank posts `neighbors` point-to-point ghost segments of
/// `ceil(ghost_elems / neighbors)` scalars each (sends and receives ride
/// the same NIC timeline, so one direction prices the exchange — matching
/// how [`ModelParams::ring`] prices the allgather's per-hop step).
/// O(surface) on the wire where the allgather ships O(n); zero with no
/// neighbors (`pr = 1`, or an operator with no cross-rank coupling).
pub fn halo_wire<S: Scalar>(p: &ModelParams, neighbors: usize, ghost_elems: usize) -> f64 {
    if neighbors == 0 {
        return 0.0;
    }
    neighbors as f64 * p.msg::<S>(ceil_div(ghost_elems, neighbors))
}

/// Shared core of the split-phase fused sparse arms: per matvec the
/// diagonal-block rows (fraction `diag_frac` of the stored entries)
/// compute while `wire` flies and the off-block rows finish on
/// completion — `max(wire, diag) + off`; the BLAS-1 chain runs the fused
/// kernels ([`sparse_iter_makespan_fused`]'s arms, term for term).
fn sparse_fused_with_wire<S: Scalar>(
    method: IterMethod,
    n: usize,
    nnz: usize,
    iters: usize,
    diag_frac: f64,
    wire: f64,
    p: &ModelParams,
) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let pr = p.shape.pr;
    let my_rows = ceil_div(kt, pr);
    let vec_elems = my_rows * t;
    let (_ring, spmv, dot, vop) = sparse_cg_terms::<S>(n, nnz, p);
    let matvec = wire.max(diag_frac * spmv) + (1.0 - diag_frac) * spmv;
    let axpy_norm2 = p.blas1_fused::<S>(vec_elems, 3, 4) + 2.0 * p.tree::<S>(pr, 1);
    let axpy_norm2_dot = p.blas1_fused::<S>(vec_elems, 4, 6) + 2.0 * p.tree::<S>(pr, 2);
    let norm2_dot = p.blas1_fused::<S>(vec_elems, 2, 4) + 2.0 * p.tree::<S>(pr, 2);
    let xpay = p.blas1_fused::<S>(vec_elems, 3, 2);
    let per_iter = match method {
        IterMethod::Cg => matvec + dot + vop + axpy_norm2 + xpay,
        IterMethod::Bicgstab => {
            2.0 * matvec + dot + axpy_norm2 + norm2_dot + 3.0 * vop + axpy_norm2_dot + xpay
        }
        _ => unreachable!("halo/split fused model covers CG and BiCGSTAB"),
    };
    iters as f64 * per_iter
}

/// Modelled makespan of `iters` fused split-phase iterations with the
/// **allgather** exchange: the wire leg is the column-comm ring of the
/// whole padded vector.  This is the halo bench's baseline arm — the same
/// overlap schedule and the same fused BLAS-1 chain as
/// [`sparse_iter_makespan_halo`], differing *only* in the wire term, so
/// the halo-vs-allgather comparison isolates exactly the neighbor
/// exchange.
pub fn sparse_iter_makespan_split<S: Scalar>(
    method: IterMethod,
    n: usize,
    nnz: usize,
    iters: usize,
    diag_frac: f64,
    p: &ModelParams,
) -> f64 {
    let (ring, _spmv, _dot, _vop) = sparse_cg_terms::<S>(n, nnz, p);
    sparse_fused_with_wire::<S>(method, n, nnz, iters, diag_frac, ring, p)
}

/// Modelled makespan of `iters` fused split-phase iterations with the
/// **neighbor (halo)** exchange ([`crate::pblas::pspmv_halo`]): the wire
/// leg is [`halo_wire`] over the exact enumerated coupling surface
/// ([`crate::workloads::stencil_halo_counts`]) instead of the O(n) ring.
/// Everything else is shared with [`sparse_iter_makespan_split`] — the
/// halo can therefore never model slower than the allgather, and wins
/// outright wherever the ring time exceeds the overlap-eligible
/// diagonal-block compute.
pub fn sparse_iter_makespan_halo<S: Scalar>(
    method: IterMethod,
    n: usize,
    nnz: usize,
    iters: usize,
    diag_frac: f64,
    neighbors: usize,
    ghost_elems: usize,
    p: &ModelParams,
) -> f64 {
    let wire = halo_wire::<S>(p, neighbors, ghost_elems);
    sparse_fused_with_wire::<S>(method, n, nnz, iters, diag_frac, wire, p)
}

/// Shared sparse-CG cost legs: (ring allgather, full local spmv, dot with
/// its reduction, local vector op).
fn sparse_cg_terms<S: Scalar>(n: usize, nnz: usize, p: &ModelParams) -> (f64, f64, f64, f64) {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let pr = p.shape.pr;
    let my_rows = ceil_div(kt, pr);
    let vec_elems = my_rows * t;
    let local_nnz = ceil_div(nnz, pr);
    let ring = p.ring::<S>(pr, vec_elems);
    let spmv = spmv_cost::<S>(&p.engine, local_nnz, vec_elems, vec_elems).total();
    let dot = my_rows as f64 * p.blas1::<S>(t) + 2.0 * p.tree::<S>(pr, 1);
    let vop = my_rows as f64 * p.blas1::<S>(t);
    (ring, spmv, dot, vop)
}

// ---- Mixed-precision twins (DESIGN.md §17) -----------------------------
//
// The refined direct flow factors in `S::Lo`, runs the two initial narrow
// substitutions, then iterates residual-correction sweeps whose residual
// accumulates in `S::Hi` on the host (the wide copy of A never leaves it);
// the mixed Krylov flow stores, computes and communicates at `S::Lo` with
// the recurrence scalars accumulated wide — the accumulators are scalars,
// so the model prices their extra width as free (a few 8-byte tree
// payloads next to vector-length legs).  Each twin gates on the same
// predicate as the live dispatch ([`crate::cluster::mixed_engaged`]'s
// dtype x profile core: a narrower dtype must exist and the engine's
// narrow arithmetic must actually be faster) and takes a `min` with its
// uniform-precision baseline, so `mixed <= uniform` holds by
// construction; where the gate is closed the twin *is* the uniform
// gpudirect twin — the exact host-arm / f32-arm wash the bench pins.

/// Refinement sweeps the refined direct twins charge.  The live loop
/// converges in 2-3 sweeps on well-conditioned operands (each sweep gains
/// ~`-log2(u_f32)` bits); the model prices the conservative end, and the
/// stagnation fallback (re-solve wide) is priced by the `min` degenerating
/// to the uniform baseline.
pub const MODEL_REFINE_ITERS: usize = 3;

/// Does the mixed flow engage at this (dtype, profile)?  The dtype x
/// engine core of the live dispatch gate: `S` must have a strictly
/// narrower storage dtype and the profile must price narrow arithmetic
/// above wide ([`ComputeProfile::mixed_advantage`] — true for the GTX 280,
/// false for the host arm).
pub fn model_mixed_engaged<S: Scalar>(p: &ModelParams) -> bool {
    crate::mixed_capable::<S>() && p.engine.mixed_advantage()
}

/// One demotion pass over `elems` local wide scalars: the narrowing
/// conversion runs on the host (dtype changes are the panel CPU's job in
/// the live flow too), one read of the wide copy plus one write of the
/// narrow one, 1 flop per element.
fn demote_pass<S: Scalar>(p: &ModelParams, elems: usize) -> f64 {
    p.panel_cpu
        .op_cost::<S>(
            OpClass::Blas1,
            elems as u64,
            elems * (S::BYTES + <S::Lo as Scalar>::BYTES),
            0,
        )
        .total()
}

/// A rank's local dense-operand share: `my_rows x my_cols` tiles.
fn local_matrix_elems(n: usize, p: &ModelParams) -> usize {
    let kt = ceil_div(n, p.tile);
    ceil_div(kt, p.shape.pr) * ceil_div(kt, p.shape.pc) * p.tile * p.tile
}

/// One iterative-refinement sweep, *less* the two narrow substitutions the
/// caller prices separately ([`trsv_resident_makespan`] at `S::Lo` — the
/// factor tiles were broadcast by the initial pair and stay resident): the
/// wide residual `r = b - A·x` — an x allgather along the row ring at
/// `S::Hi` width, one wide host gemv pass over the owned tiles (the wide
/// copy of A is host-resident, exactly like the live refined loop), the
/// column-tree reduction of the row partials — plus the norm reduction
/// driving the convergence test and two wide BLAS-1 passes (demote the
/// residual to the solve dtype, apply the promoted correction to x).
fn refine_sweep<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    let t = p.tile;
    let kt = ceil_div(n, t);
    let (pr, pc) = (p.shape.pr, p.shape.pc);
    let my_rows = ceil_div(kt, pr);
    let my_cols = ceil_div(kt, pc);
    let vec_elems = my_rows * t;
    let hb = <S::Hi as Scalar>::BYTES;
    let tile_gemv = p
        .panel_cpu
        .op_cost::<S::Hi>(OpClass::Blas2, 2 * (t * t) as u64, (t * t + 2 * t) * hb, 0)
        .total();
    p.ring::<S::Hi>(pr, vec_elems)
        + (my_rows * my_cols) as f64 * tile_gemv
        + 2.0 * p.tree::<S::Hi>(pc, vec_elems)
        + 2.0 * p.blas1::<S::Hi>(vec_elems)
        + 2.0 * p.tree::<S::Hi>(pr, 1)
}

/// Mixed-precision twin of [`lu_makespan_gpudirect`]: demote the local A
/// share, factor + solve entirely at `S::Lo` (narrow flops *and* narrow
/// PCIe/wire bytes — the reduced-precision communication leg), then
/// [`MODEL_REFINE_ITERS`] wide refinement sweeps of residual + two narrow
/// substitutions each (priced resident — [`trsv_resident_makespan`] — the
/// initial narrow pair inside the factorization twin already broadcast the
/// factor tiles).  `<=` the uniform twin by construction (`min`),
/// strict on the accelerated arm at paper scale (the O(n³) factor moves
/// from DGEMM to SGEMM rates while the refine overhead is O(n²)), and an
/// exact wash wherever the gate is closed — host profiles and `f32`
/// operands, where this *is* [`lu_makespan_gpudirect`].
pub fn lu_makespan_refined<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    let uniform = lu_makespan_gpudirect::<S>(n, p);
    if !model_mixed_engaged::<S>(p) {
        return uniform;
    }
    let mixed = demote_pass::<S>(p, local_matrix_elems(n, p))
        + lu_makespan_gpudirect::<S::Lo>(n, p)
        + MODEL_REFINE_ITERS as f64
            * (refine_sweep::<S>(n, p) + 2.0 * trsv_resident_makespan::<S::Lo>(n, p));
    mixed.min(uniform)
}

/// Mixed-precision twin of [`chol_makespan_gpudirect`] — same construction
/// as [`lu_makespan_refined`].
pub fn chol_makespan_refined<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    let uniform = chol_makespan_gpudirect::<S>(n, p);
    if !model_mixed_engaged::<S>(p) {
        return uniform;
    }
    let mixed = demote_pass::<S>(p, local_matrix_elems(n, p))
        + chol_makespan_gpudirect::<S::Lo>(n, p)
        + MODEL_REFINE_ITERS as f64
            * (refine_sweep::<S>(n, p) + 2.0 * trsv_resident_makespan::<S::Lo>(n, p));
    mixed.min(uniform)
}

/// Mixed-precision twin of [`iter_makespan_gpudirect`] for the
/// f32-storage / f64-accumulate Krylov solvers (CG and BiCGSTAB — the
/// methods the live `cg_mixed` / `bicgstab_mixed` cover): one demotion
/// pass over the local A share, then the whole iteration at `S::Lo` —
/// narrow matvec streams, narrow allgather/allreduce payloads (the
/// reduced-precision wire), narrow vector passes.  The wide accumulators
/// are scalars and price as free.  `<=` the uniform twin by construction,
/// strict on the accelerated arm, exact wash where the gate is closed or
/// the method is uncovered.
pub fn iter_makespan_mixed<S: Scalar>(
    method: IterMethod,
    n: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    let uniform = iter_makespan_gpudirect::<S>(method, n, iters, restart, p);
    if !model_mixed_engaged::<S>(p)
        || !matches!(method, IterMethod::Cg | IterMethod::Bicgstab)
    {
        return uniform;
    }
    let mixed = demote_pass::<S>(p, local_matrix_elems(n, p))
        + iter_makespan_gpudirect::<S::Lo>(method, n, iters, restart, p);
    mixed.min(uniform)
}

/// Mixed-precision twin of [`sparse_iter_makespan_gpudirect`]: the narrow
/// win here is the halved CSR value stream and the halved x-allgather
/// payload (the memory-bound regime where bytes are the whole price); the
/// demotion pass covers the rank's `~nnz/pr` stored values.  Same gate and
/// `min` construction as [`iter_makespan_mixed`].
pub fn sparse_iter_makespan_mixed<S: Scalar>(
    method: IterMethod,
    n: usize,
    nnz: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    let uniform = sparse_iter_makespan_gpudirect::<S>(method, n, nnz, iters, restart, p);
    if !model_mixed_engaged::<S>(p)
        || !matches!(method, IterMethod::Cg | IterMethod::Bicgstab)
    {
        return uniform;
    }
    let mixed = demote_pass::<S>(p, ceil_div(nnz, p.shape.pr))
        + sparse_iter_makespan_gpudirect::<S::Lo>(method, n, nnz, iters, restart, p);
    mixed.min(uniform)
}

// ---- Fault-tolerance twins (DESIGN.md §18) -----------------------------
//
// The checkpointed flows add, on top of the full-featured gpudirect twins,
// one priced D2H leg per checkpoint (the live `Ctx::snapshot_read` of the
// rank's local operand share — 0 on host profiles, where the state is
// already host-resident and a snapshot is a memcpy the virtual clock does
// not price).  Fault-free overhead is therefore *exactly* the leg sum, by
// construction — the equality BENCH_faults.json pins term for term.
//
// Recovery is priced on the virtual timeline: a crash at panel (iteration)
// `c` costs the fault-free run, plus the reboot charge, plus a *replay
// span* — panels `[0, c)` for the recompute-from-scratch arm, panels
// `[last_ckpt, c)` plus one restore leg for the checkpointed arm.  With the
// crash landing at or past the first checkpoint the replayed prefix shrinks
// by at least `every` panels of BLAS-3 (matvec) work against a handful of
// O(local-share) PCIe legs, so `ckpt_recovery < full_recovery` strictly —
// the inequality the bench asserts on every grid point.

/// One direct-method checkpoint leg: D2H of the rank's local tile share
/// (what `plu_factor_ckpt` / `pchol_factor_ckpt` snapshot).  0 on host
/// profiles.
pub fn ckpt_leg<S: Scalar>(n: usize, p: &ModelParams) -> f64 {
    p.xfer::<S>(local_matrix_elems(n, p))
}

/// Panel count of an `n x n` factorisation (checkpoint slots: `0, e, 2e,
/// ...` — the boundary-`0` checkpoint included, matching the live loop).
pub fn n_panels(n: usize, p: &ModelParams) -> usize {
    ceil_div(n, p.tile)
}

/// Checkpoints a fault-free run writes: one per `every` panels, panel 0
/// included.
pub fn n_checkpoints(panels: usize, every: usize) -> usize {
    ceil_div(panels, every.max(1))
}

/// Replay span of LU panels `[from, to)` — the identical per-step terms of
/// the resident/prefetch flow the gpudirect twin assembles.
fn lu_span<S: Scalar>(n: usize, p: &ModelParams, from: usize, to: usize) -> f64 {
    lu_step_parts::<S>(n, p, true)[from..to]
        .iter()
        .map(|&(cpu, comm, pre, uc, up)| cpu + comm + pre + uc.max(up))
        .sum()
}

/// Replay span of Cholesky panels `[from, to)`.
fn chol_span<S: Scalar>(n: usize, p: &ModelParams, from: usize, to: usize) -> f64 {
    (from..to).fold(0.0, |acc, k| chol_step_cost::<S>(n, p, k, true, f64::max, acc))
}

/// Checkpointed twin of [`lu_makespan_gpudirect`]: the same makespan plus
/// one D2H leg per checkpoint.  Fault-free overhead over the base twin is
/// exactly `n_checkpoints · ckpt_leg` — nothing else changes.
pub fn lu_makespan_ckpt<S: Scalar>(n: usize, every: usize, p: &ModelParams) -> f64 {
    lu_makespan_gpudirect::<S>(n, p)
        + n_checkpoints(n_panels(n, p), every) as f64 * ckpt_leg::<S>(n, p)
}

/// Checkpointed twin of [`chol_makespan_gpudirect`].
pub fn chol_makespan_ckpt<S: Scalar>(n: usize, every: usize, p: &ModelParams) -> f64 {
    chol_makespan_gpudirect::<S>(n, p)
        + n_checkpoints(n_panels(n, p), every) as f64 * ckpt_leg::<S>(n, p)
}

/// Recovery cost of an un-checkpointed LU run whose crash lands at panel
/// `crash`: the fault-free run, the reboot, and a full replay of panels
/// `[0, crash)` — everything the dead rank's restart recomputes.
pub fn lu_recovery_full<S: Scalar>(
    n: usize,
    crash: usize,
    reboot: f64,
    p: &ModelParams,
) -> f64 {
    lu_makespan_gpudirect::<S>(n, p) + reboot + lu_span::<S>(n, p, 0, crash)
}

/// Recovery cost of the checkpointed LU run: the (checkpoint-taxed)
/// fault-free run, the reboot, one restore leg (H2D of the snapshot — same
/// bytes as the D2H that wrote it), and a replay of only
/// `[last_checkpoint, crash)`.
pub fn lu_recovery_ckpt<S: Scalar>(
    n: usize,
    every: usize,
    crash: usize,
    reboot: f64,
    p: &ModelParams,
) -> f64 {
    let last = (crash / every.max(1)) * every.max(1);
    lu_makespan_ckpt::<S>(n, every, p)
        + reboot
        + ckpt_leg::<S>(n, p)
        + lu_span::<S>(n, p, last, crash)
}

/// Recovery cost of an un-checkpointed Cholesky run — same construction as
/// [`lu_recovery_full`].
pub fn chol_recovery_full<S: Scalar>(
    n: usize,
    crash: usize,
    reboot: f64,
    p: &ModelParams,
) -> f64 {
    chol_makespan_gpudirect::<S>(n, p) + reboot + chol_span::<S>(n, p, 0, crash)
}

/// Recovery cost of the checkpointed Cholesky run — same construction as
/// [`lu_recovery_ckpt`].
pub fn chol_recovery_ckpt<S: Scalar>(
    n: usize,
    every: usize,
    crash: usize,
    reboot: f64,
    p: &ModelParams,
) -> f64 {
    let last = (crash / every.max(1)) * every.max(1);
    chol_makespan_ckpt::<S>(n, every, p)
        + reboot
        + ckpt_leg::<S>(n, p)
        + chol_span::<S>(n, p, last, crash)
}

/// One Krylov snapshot leg: D2H of the solver's saved state — CG and
/// BiCGSTAB snapshot three local vector blocks (x, r, p), GMRES snapshots
/// x alone at each cycle boundary.  0 on host profiles and for methods
/// without a fault-tolerant variant.
pub fn krylov_snap_leg<S: Scalar>(method: IterMethod, n: usize, p: &ModelParams) -> f64 {
    let vecs = match method {
        IterMethod::Cg | IterMethod::Bicgstab => 3,
        IterMethod::Gmres => 1,
        _ => 0,
    };
    let vec_elems = ceil_div(ceil_div(n, p.tile), p.shape.pr) * p.tile;
    vecs as f64 * p.xfer::<S>(vec_elems)
}

/// The snapshot period the live solver actually uses: GMRES snapshots at
/// every restart cycle (the policy's period is ignored — `m` is the rework
/// bound), CG/BiCGSTAB honor `every`.
pub fn krylov_snap_period(method: IterMethod, every: usize, restart: usize) -> usize {
    match method {
        IterMethod::Gmres => restart.max(1),
        _ => every.max(1),
    }
}

/// Checkpointed twin of [`iter_makespan_gpudirect`]: one snapshot leg per
/// period, iteration 0 included.  Fault-free overhead over the base twin
/// is exactly the leg sum.
pub fn iter_makespan_ckpt<S: Scalar>(
    method: IterMethod,
    n: usize,
    iters: usize,
    restart: usize,
    every: usize,
    p: &ModelParams,
) -> f64 {
    let period = krylov_snap_period(method, every, restart);
    iter_makespan_gpudirect::<S>(method, n, iters, restart, p)
        + n_checkpoints(iters, period) as f64 * krylov_snap_leg::<S>(method, n, p)
}

/// Recovery cost of an un-snapshotted Krylov run whose crash lands at
/// iteration `crash`: fault-free run + reboot + replay of `[0, crash)`.
pub fn iter_recovery_full<S: Scalar>(
    method: IterMethod,
    n: usize,
    iters: usize,
    restart: usize,
    crash: usize,
    reboot: f64,
    p: &ModelParams,
) -> f64 {
    iter_makespan_gpudirect::<S>(method, n, iters, restart, p)
        + reboot
        + iter_makespan_gpudirect::<S>(method, n, crash, restart, p)
}

/// Recovery cost of the snapshotted Krylov run: the (snapshot-taxed)
/// fault-free run + reboot + one restore leg + replay of only
/// `[last_snapshot, crash)` — at most one period (one GMRES cycle) of
/// iterations.
pub fn iter_recovery_ckpt<S: Scalar>(
    method: IterMethod,
    n: usize,
    iters: usize,
    restart: usize,
    every: usize,
    crash: usize,
    reboot: f64,
    p: &ModelParams,
) -> f64 {
    let period = krylov_snap_period(method, every, restart);
    let last = (crash / period) * period;
    iter_makespan_ckpt::<S>(method, n, iters, restart, every, p)
        + reboot
        + krylov_snap_leg::<S>(method, n, p)
        + iter_makespan_gpudirect::<S>(method, n, crash - last, restart, p)
}

/// Modelled makespan for a (method, engine) arm.
pub fn method_makespan<S: Scalar>(
    method: crate::cluster::Method,
    n: usize,
    iters: usize,
    restart: usize,
    p: &ModelParams,
) -> f64 {
    match method {
        crate::cluster::Method::Lu => lu_makespan::<S>(n, p),
        crate::cluster::Method::Cholesky => chol_makespan::<S>(n, p),
        crate::cluster::Method::Iterative(m) => iter_makespan::<S>(m, n, iters, restart, p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(ranks: usize, gpu: bool) -> ModelParams {
        ModelParams {
            tile: 256,
            shape: MeshShape::near_square(ranks),
            net: NetworkModel::gigabit_ethernet(),
            engine: if gpu {
                ComputeProfile::gtx280_cublas()
            } else {
                ComputeProfile::q6600_atlas()
            },
            panel_cpu: ComputeProfile::q6600_atlas(),
            swap_fraction: 0.5,
            device_mem: crate::accel::DEFAULT_DEVICE_MEM,
        }
    }

    #[test]
    fn lu_scales_down_with_ranks() {
        let n = 8192;
        let t1 = lu_makespan::<f32>(n, &params(1, false));
        let t4 = lu_makespan::<f32>(n, &params(4, false));
        let t16 = lu_makespan::<f32>(n, &params(16, false));
        assert!(t4 < t1 && t16 < t4, "{t1} {t4} {t16}");
        // sub-linear (communication overhead)
        assert!(t1 / t16 < 16.0);
        assert!(t1 / t16 > 2.0);
    }

    #[test]
    fn gpu_arm_faster_but_not_dramatically() {
        // The paper's core observation at n = 60000.
        let n = 60_000;
        let cpu = lu_makespan::<f32>(n, &params(16, false));
        let gpu = lu_makespan::<f32>(n, &params(16, true));
        let ratio = cpu / gpu;
        assert!(ratio > 1.0, "CUDA arm must win: {ratio}");
        assert!(ratio < 30.0, "but transfers cap the gain: {ratio}");
    }

    #[test]
    fn iterative_scales() {
        let n = 16_384;
        let t1 = iter_makespan::<f32>(IterMethod::Bicgstab, n, 100, 30, &params(1, false));
        let t16 = iter_makespan::<f32>(IterMethod::Bicgstab, n, 100, 30, &params(16, false));
        assert!(t16 < t1);
        assert!(t1 / t16 < 16.0);
    }

    #[test]
    fn dp_slower_than_sp() {
        let n = 30_000;
        let sp = lu_makespan::<f32>(n, &params(8, true));
        let dp = lu_makespan::<f64>(n, &params(8, true));
        assert!(dp > sp, "{dp} vs {sp}");
    }

    #[test]
    fn trsv_minor_vs_factorisation() {
        let n = 30_000;
        let p = params(8, false);
        assert!(trsv_makespan::<f32>(n, &p) < 0.1 * lu_makespan::<f32>(n, &p));
    }

    #[test]
    fn overlap_never_loses_and_lookahead_strictly_wins_on_gigabit() {
        // Acceptance shape of BENCH_overlap.json: overlapped <= blocking on
        // every modeled configuration; strictly smaller for LU lookahead
        // and pipelined CG on the gigabit network.
        let g = 1_000usize;
        let (sn, nnz) = (g * g, 5 * g * g - 4 * g);
        // Relative slack for the <= checks: at P=1 the overlapped and
        // blocking formulas sum identical terms in different association
        // orders, so they agree only to round-off.
        let le = |o: f64, b: f64| o <= b * (1.0 + 1e-9);
        for ranks in [1usize, 2, 4, 8, 16] {
            for gpu in [false, true] {
                let p = params(ranks, gpu);
                let (lu_b, lu_o) =
                    (lu_makespan::<f32>(30_000, &p), lu_makespan_lookahead::<f32>(30_000, &p));
                assert!(le(lu_o, lu_b), "LU P={ranks} gpu={gpu}: {lu_o} vs {lu_b}");
                let (sm_b, sm_o) = (
                    summa_makespan::<f32>(16_384, &p, false),
                    summa_makespan::<f32>(16_384, &p, true),
                );
                assert!(le(sm_o, sm_b), "SUMMA P={ranks} gpu={gpu}: {sm_o} vs {sm_b}");
                if !gpu {
                    let cg_b = sparse_iter_makespan::<f64>(IterMethod::Cg, sn, nnz, 100, 30, &p);
                    let cg_o = sparse_cg_split_makespan::<f64>(sn, nnz, 100, 0.9, &p);
                    assert!(le(cg_o, cg_b), "sparse CG P={ranks}: {cg_o} vs {cg_b}");
                    let pc_b =
                        sparse_iter_makespan::<f64>(IterMethod::PipeCg, sn, nnz, 100, 30, &p);
                    let pc_o = sparse_pipecg_overlap_makespan::<f64>(sn, nnz, 100, 0.9, &p);
                    assert!(le(pc_o, pc_b), "pipecg P={ranks}: {pc_o} vs {pc_b}");
                    if p.shape.pr > 1 {
                        // With >1 process row there is a reduction tree and
                        // an exchange to hide: the win must be strict.
                        assert!(pc_o < pc_b, "pipecg must strictly win at P={ranks}");
                    }
                }
                if ranks > 1 {
                    assert!(lu_o < lu_b, "LU lookahead must strictly win at P={ranks}");
                }
            }
        }
        // At P=1 there is no network to hide and the host getrf stays on
        // the (single) compute timeline, so the lookahead schedule costs
        // exactly the blocking one — which is also what the live simulator
        // produces (identical op set on one clock).
        let p1 = params(1, false);
        let (b1, o1) =
            (lu_makespan::<f32>(30_000, &p1), lu_makespan_lookahead::<f32>(30_000, &p1));
        assert!((o1 - b1).abs() < 1e-9 * b1, "P=1 must be a wash: {o1} vs {b1}");
    }

    #[test]
    fn halo_wire_degenerates_and_undercuts_the_ring() {
        let p = params(8, false);
        assert_eq!(halo_wire::<f64>(&p, 0, 0), 0.0, "no neighbors, no wire");
        assert_eq!(halo_wire::<f64>(&p, 0, 10_000), 0.0, "pr = 1 ships nothing");
        // A stencil surface against the O(n) ring it replaces.
        let pr = p.shape.pr;
        let vec_elems = ceil_div(ceil_div(262_144, p.tile), pr) * p.tile;
        let ring = p.ring::<f64>(pr, vec_elems);
        let wire = halo_wire::<f64>(&p, 2, 2 * p.tile);
        assert!(wire < ring, "surface wire {wire} must undercut ring {ring}");
    }

    #[test]
    fn halo_never_loses_and_wins_at_scale_on_gigabit() {
        // Acceptance shape of BENCH_halo.json: halo <= allgather on every
        // modeled configuration, strictly smaller wherever P >= 4 on the
        // gigabit network (there the ring wire dominates the overlapped
        // diagonal-block compute; the halo's O(surface) wire hides under
        // it entirely), and an exact wash at pr = 1 (zero wire both arms).
        use crate::workloads::stencil_halo_counts;
        let le = |h: f64, a: f64| h <= a * (1.0 + 1e-9);
        let iters = 100;
        for ranks in [1usize, 2, 4, 8, 16] {
            let p = params(ranks, false);
            let pr = p.shape.pr;
            for (g, dim) in [(512usize, 2u32), (64, 3)] {
                let n = g.pow(dim);
                let h = stencil_halo_counts(g, dim, p.tile, pr);
                let diag_frac = h.diag_nnz as f64 / h.total_nnz as f64;
                for m in [IterMethod::Cg, IterMethod::Bicgstab] {
                    let ag = sparse_iter_makespan_split::<f64>(
                        m, n, h.total_nnz, iters, diag_frac, &p,
                    );
                    let ha = sparse_iter_makespan_halo::<f64>(
                        m,
                        n,
                        h.total_nnz,
                        iters,
                        diag_frac,
                        h.neighbors,
                        h.ghost_elems,
                        &p,
                    );
                    assert!(le(ha, ag), "P={ranks} g={g} dim={dim} {m:?}: {ha} vs {ag}");
                    if pr >= 2 {
                        assert!(
                            ha < ag,
                            "halo must strictly win at P={ranks} (pr={pr}) g={g} dim={dim}"
                        );
                    } else {
                        assert!(
                            (ha - ag).abs() <= 1e-12 * ag.max(1.0),
                            "pr=1 must be an exact wash: {ha} vs {ag}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn residency_twins_never_lose_and_win_on_the_accelerated_arm() {
        // Acceptance shape of BENCH_residency.json: the residency/fusion
        // twins are <= the streaming (paper §3 flow) models on every
        // configuration; strictly smaller wherever there is a PCIe link
        // (tile residency) and, for the fused solvers, on the host arm too
        // (collapsed launches + memory passes).
        let le = |c: f64, s: f64| c <= s * (1.0 + 1e-9);
        for ranks in [1usize, 2, 4, 8, 16] {
            for gpu in [false, true] {
                let p = params(ranks, gpu);
                let n = 30_000usize;
                let (lu_s, lu_c) =
                    (lu_makespan_lookahead::<f32>(n, &p), lu_makespan_resident::<f32>(n, &p));
                assert!(le(lu_c, lu_s), "LU P={ranks} gpu={gpu}: {lu_c} vs {lu_s}");
                let (ch_s, ch_c) =
                    (chol_makespan::<f32>(n, &p), chol_makespan_resident::<f32>(n, &p));
                assert!(le(ch_c, ch_s), "Chol P={ranks} gpu={gpu}: {ch_c} vs {ch_s}");
                let (sm_s, sm_c) = (
                    summa_makespan::<f32>(16_384, &p, true),
                    summa_makespan_resident::<f32>(16_384, &p, true),
                );
                assert!(le(sm_c, sm_s), "SUMMA P={ranks} gpu={gpu}: {sm_c} vs {sm_s}");
                for m in [IterMethod::Cg, IterMethod::PipeCg, IterMethod::Bicgstab] {
                    let s = iter_makespan::<f32>(m, n, 100, 30, &p);
                    let c = iter_makespan_fused::<f32>(m, n, 100, 30, &p);
                    assert!(le(c, s), "{m:?} P={ranks} gpu={gpu}: {c} vs {s}");
                    // Fused solvers win on both arms (launches + passes).
                    assert!(c < s, "{m:?} P={ranks} gpu={gpu} must strictly win");
                }
                if gpu {
                    // Tile residency must strictly beat copy-per-call.
                    assert!(lu_c < lu_s, "LU residency must win at P={ranks}");
                    assert!(ch_c < ch_s, "Chol residency must win at P={ranks}");
                    assert!(sm_c < sm_s, "SUMMA residency must win at P={ranks}");
                } else {
                    // Host arm: nothing streams either way — exact wash.
                    assert!((lu_c - lu_s).abs() <= 1e-9 * lu_s, "{lu_c} vs {lu_s}");
                    assert!((ch_c - ch_s).abs() <= 1e-9 * ch_s, "{ch_c} vs {ch_s}");
                }
            }
        }
    }

    #[test]
    fn prefetch_twins_never_lose_and_win_wherever_residency_paid_pcie() {
        // Acceptance shape of BENCH_prefetch.json: prefetch <= resident <=
        // streaming on every configuration; prefetch strictly smaller than
        // resident wherever residency still paid PCIe on the compute
        // timeline (the accelerated arm), and *exactly* equal on host
        // profiles (the copy engine has nothing to carry).
        let le = |a: f64, b: f64| a <= b * (1.0 + 1e-9);
        for ranks in [1usize, 2, 4, 8, 16] {
            for gpu in [false, true] {
                let p = params(ranks, gpu);
                let n = 30_000usize;
                let (lu_r, lu_p) =
                    (lu_makespan_resident::<f32>(n, &p), lu_makespan_prefetch::<f32>(n, &p));
                assert!(le(lu_p, lu_r), "LU P={ranks} gpu={gpu}: {lu_p} vs {lu_r}");
                let (ch_r, ch_p) =
                    (chol_makespan_resident::<f32>(n, &p), chol_makespan_prefetch::<f32>(n, &p));
                assert!(le(ch_p, ch_r), "Chol P={ranks} gpu={gpu}: {ch_p} vs {ch_r}");
                let (sm_r, sm_p) = (
                    summa_makespan_resident::<f32>(16_384, &p, true),
                    summa_makespan_prefetch::<f32>(16_384, &p, true),
                );
                assert!(le(sm_p, sm_r), "SUMMA P={ranks} gpu={gpu}: {sm_p} vs {sm_r}");
                for m in [IterMethod::Cg, IterMethod::PipeCg, IterMethod::Bicgstab] {
                    let r = iter_makespan_fused::<f32>(m, n, 100, 30, &p);
                    let pf = iter_makespan_prefetch::<f32>(m, n, 100, 30, &p);
                    assert!(le(pf, r), "{m:?} P={ranks} gpu={gpu}: {pf} vs {r}");
                    // And the full chain holds.
                    assert!(le(pf, iter_makespan::<f32>(m, n, 100, 30, &p)));
                    if gpu {
                        assert!(pf < r, "{m:?} P={ranks}: prefetch must strictly win");
                    } else {
                        assert_eq!(pf, r, "{m:?} P={ranks}: host arm must be exact");
                    }
                }
                if gpu {
                    // LU: strict exactly where residency left PCIe on the
                    // critical path (the comm lookahead hides the trailing
                    // leg outright at large rank counts) — and the
                    // headroom predicate must agree with the outcome.
                    if lu_prefetch_headroom::<f32>(n, &p) {
                        assert!(lu_p < lu_r, "LU prefetch must win at P={ranks}");
                    } else {
                        assert_eq!(lu_p, lu_r, "no headroom: LU must be a wash");
                    }
                    assert!(ch_p < ch_r, "Chol prefetch must win at P={ranks}");
                    assert!(sm_p < sm_r, "SUMMA prefetch must win at P={ranks}");
                } else {
                    assert_eq!(lu_p, lu_r, "host LU must be an exact wash");
                    assert_eq!(ch_p, ch_r, "host Chol must be an exact wash");
                    assert_eq!(sm_p, sm_r, "host SUMMA must be an exact wash");
                }
            }
        }
        // Sparse rows: host-side operands, copy engine idle — identical by
        // definition.
        let g = 1_000usize;
        let (sn, nnz) = (g * g, 5 * g * g - 4 * g);
        let p = params(4, false);
        assert_eq!(
            sparse_iter_makespan_prefetch::<f64>(IterMethod::Cg, sn, nnz, 100, 30, &p),
            sparse_iter_makespan_fused::<f64>(IterMethod::Cg, sn, nnz, 100, 30, &p),
        );
    }

    #[test]
    fn gpudirect_twins_never_lose_and_win_where_dirty_payloads_hit_the_wire() {
        // Acceptance shape of BENCH_gpudirect.json: on every configuration
        // `gpudirect <= prefetch + wire_stage` (the host-staged arm);
        // strictly smaller exactly where a device-dirty payload hits the
        // wire (`stage > 0`); and an exact wash on host profiles and for
        // the host-clean-payload kernels (SUMMA, halo-sparse).
        let le = |a: f64, b: f64| a <= b * (1.0 + 1e-9);
        let n = 30_000usize;
        for ranks in [1usize, 2, 4, 8, 16] {
            for gpu in [false, true] {
                let p = params(ranks, gpu);
                let (pr, pc) = (p.shape.pr, p.shape.pc);

                let lu_staged = lu_makespan_prefetch::<f32>(n, &p) + lu_wire_stage::<f32>(n, &p);
                let lu_g = lu_makespan_gpudirect::<f32>(n, &p);
                assert!(le(lu_g, lu_staged), "LU P={ranks} gpu={gpu}: {lu_g} vs {lu_staged}");
                if gpu && pr > 1 {
                    assert!(lu_wire_stage::<f32>(n, &p) > 0.0);
                    assert!(lu_g < lu_staged, "LU gpudirect must strictly win at P={ranks}");
                } else {
                    // pr = 1 sends no panel columns: nothing stages.
                    assert_eq!(lu_wire_stage::<f32>(n, &p), 0.0);
                    assert_eq!(lu_g, lu_staged, "no dirty payload: LU must be an exact wash");
                }

                let ch_staged =
                    chol_makespan_prefetch::<f32>(n, &p) + chol_wire_stage::<f32>(n, &p);
                let ch_g = chol_makespan_gpudirect::<f32>(n, &p);
                assert!(le(ch_g, ch_staged), "Chol P={ranks} gpu={gpu}: {ch_g} vs {ch_staged}");
                if gpu && ranks > 1 {
                    assert!(chol_wire_stage::<f32>(n, &p) > 0.0);
                    assert!(ch_g < ch_staged, "Chol gpudirect must strictly win at P={ranks}");
                } else {
                    assert_eq!(chol_wire_stage::<f32>(n, &p), 0.0);
                    assert_eq!(ch_g, ch_staged, "no dirty payload: Chol must be an exact wash");
                }

                // SUMMA ships read-only, host-clean panels: exact wash by
                // definition, on both arms.
                assert_eq!(summa_wire_stage::<f32>(16_384, &p), 0.0);
                assert_eq!(
                    summa_makespan_gpudirect::<f32>(16_384, &p, true),
                    summa_makespan_prefetch::<f32>(16_384, &p, true),
                );

                for m in [IterMethod::Cg, IterMethod::Bicgstab] {
                    let staged = iter_makespan_prefetch::<f32>(m, n, 100, 30, &p)
                        + iter_wire_stage::<f32>(m, n, 100, &p);
                    let g = iter_makespan_gpudirect::<f32>(m, n, 100, 30, &p);
                    assert!(le(g, staged), "{m:?} P={ranks} gpu={gpu}: {g} vs {staged}");
                    if gpu && pc > 1 {
                        assert!(g < staged, "{m:?} P={ranks}: gpudirect must strictly win");
                    } else {
                        assert_eq!(g, staged, "{m:?} P={ranks}: must be an exact wash");
                    }
                }
            }
        }
        // Halo-sparse rows: host-arm operands, host-clean ghost segments —
        // identical by definition.
        let g = 1_000usize;
        let (sn, nnz) = (g * g, 5 * g * g - 4 * g);
        let p = params(4, false);
        assert_eq!(sparse_iter_wire_stage::<f64>(sn, nnz, &p), 0.0);
        assert_eq!(
            sparse_iter_makespan_gpudirect::<f64>(IterMethod::Cg, sn, nnz, 100, 30, &p),
            sparse_iter_makespan_prefetch::<f64>(IterMethod::Cg, sn, nnz, 100, 30, &p),
        );
        // BiCGSTAB pays the wire twice per iteration.
        let p16 = params(16, true);
        assert!(
            iter_wire_stage::<f32>(IterMethod::Bicgstab, n, 100, &p16)
                > iter_wire_stage::<f32>(IterMethod::Cg, n, 100, &p16)
        );
    }

    #[test]
    fn mixed_twins_never_lose_strict_on_cuda_and_exact_wash_where_gated() {
        // Acceptance shape of BENCH_mixed.json: mixed <= f64 on every
        // modeled configuration; strictly smaller on the accelerated arm
        // (where the gate opens: SGEMM 6x DGEMM + halved PCIe/wire bytes
        // dwarf the O(n²) refine overhead); and *exactly* the uniform
        // gpudirect twin wherever the gate is closed — host profiles, f32
        // operands (no narrower dtype), uncovered methods.
        let le = |m: f64, u: f64| m <= u * (1.0 + 1e-9);
        let n = 30_000usize;
        let g = 1_000usize;
        let (sn, nnz) = (g * g, 5 * g * g - 4 * g);
        for ranks in [1usize, 2, 4, 8, 16] {
            for gpu in [false, true] {
                let p = params(ranks, gpu);
                assert_eq!(model_mixed_engaged::<f64>(&p), gpu);
                assert!(!model_mixed_engaged::<f32>(&p), "f32 is its own floor");

                let (lu_m, lu_u) =
                    (lu_makespan_refined::<f64>(n, &p), lu_makespan_gpudirect::<f64>(n, &p));
                assert!(le(lu_m, lu_u), "LU P={ranks} gpu={gpu}: {lu_m} vs {lu_u}");
                let (ch_m, ch_u) =
                    (chol_makespan_refined::<f64>(n, &p), chol_makespan_gpudirect::<f64>(n, &p));
                assert!(le(ch_m, ch_u), "Chol P={ranks} gpu={gpu}: {ch_m} vs {ch_u}");
                if gpu {
                    assert!(lu_m < lu_u, "LU refined must strictly win at P={ranks}");
                    assert!(ch_m < ch_u, "Chol refined must strictly win at P={ranks}");
                } else {
                    // Gate closed: the twin IS the uniform twin.
                    assert_eq!(lu_m, lu_u, "host LU must be an exact wash");
                    assert_eq!(ch_m, ch_u, "host Chol must be an exact wash");
                }
                // f32 operands: no narrower dtype — exact wash on both arms.
                assert_eq!(
                    lu_makespan_refined::<f32>(n, &p),
                    lu_makespan_gpudirect::<f32>(n, &p),
                );

                for m in [IterMethod::Cg, IterMethod::Bicgstab] {
                    let im = iter_makespan_mixed::<f64>(m, n, 100, 30, &p);
                    let iu = iter_makespan_gpudirect::<f64>(m, n, 100, 30, &p);
                    assert!(le(im, iu), "{m:?} P={ranks} gpu={gpu}: {im} vs {iu}");
                    let sm = sparse_iter_makespan_mixed::<f64>(m, sn, nnz, 100, 30, &p);
                    let su = sparse_iter_makespan_gpudirect::<f64>(m, sn, nnz, 100, 30, &p);
                    assert!(le(sm, su), "sparse {m:?} P={ranks} gpu={gpu}: {sm} vs {su}");
                    if gpu {
                        assert!(im < iu, "{m:?} P={ranks}: mixed must strictly win");
                        assert!(sm < su, "sparse {m:?} P={ranks}: mixed must strictly win");
                    } else {
                        assert_eq!(im, iu, "{m:?} P={ranks}: host must be an exact wash");
                        assert_eq!(sm, su, "sparse {m:?} P={ranks}: host exact wash");
                    }
                }
                // Uncovered method: falls through to the uniform twin.
                assert_eq!(
                    iter_makespan_mixed::<f64>(IterMethod::Gmres, n, 50, 30, &p),
                    iter_makespan_gpudirect::<f64>(IterMethod::Gmres, n, 50, 30, &p),
                );
            }
        }
        // The paper-scale acceptance point: n = 60000, 16 ranks, CUDA arm —
        // the refined factor must recover most of the SGEMM/DGEMM gap.
        let p16 = params(16, true);
        let (m, u) =
            (lu_makespan_refined::<f64>(60_000, &p16), lu_makespan_gpudirect::<f64>(60_000, &p16));
        assert!(m < u, "paper-scale refined LU must win: {m} vs {u}");
        assert!(u / m > 1.5, "the win must be substantial, got {:.2}x", u / m);
    }

    #[test]
    fn sparse_fused_twin_wins_on_launch_count() {
        // Sparse operands run host-side, so the fused twin's whole gain is
        // the collapsed BLAS-1 chain — still a strict win.
        let g = 1_000usize;
        let (n, nnz) = (g * g, 5 * g * g - 4 * g);
        for ranks in [1usize, 4, 16] {
            let p = params(ranks, false);
            for m in [IterMethod::Cg, IterMethod::PipeCg, IterMethod::Bicgstab] {
                let s = sparse_iter_makespan::<f64>(m, n, nnz, 100, 30, &p);
                let c = sparse_iter_makespan_fused::<f64>(m, n, nnz, 100, 30, &p);
                assert!(c < s, "{m:?} P={ranks}: fused {c} vs {s}");
            }
            // Untouched methods fall back to the streaming model.
            let s = sparse_iter_makespan::<f64>(IterMethod::Gmres, n, nnz, 50, 30, &p);
            let c = sparse_iter_makespan_fused::<f64>(IterMethod::Gmres, n, nnz, 50, 30, &p);
            assert_eq!(s, c);
        }
    }

    #[test]
    fn device_budget_gates_the_dense_matvec_residency() {
        // With the 1 GiB GTX 280 budget, a rank's share of the n=60000 f32
        // matrix fits only at P=16 — the twin must charge the one-time A
        // load there and fall back to streaming below.
        let n = 60_000usize;
        let fits = |ranks: usize| {
            let p = params(ranks, true);
            let kt = crate::dist::ceil_div(n, p.tile);
            let tiles = crate::dist::ceil_div(kt, p.shape.pr)
                * crate::dist::ceil_div(kt, p.shape.pc);
            tiles * p.tile * p.tile * 4 <= p.device_mem
        };
        assert!(!fits(1) && fits(16));
        // Either way the fused twin never exceeds the streaming model.
        for ranks in [1usize, 16] {
            let p = params(ranks, true);
            let s = iter_makespan::<f32>(IterMethod::Cg, n, 100, 30, &p);
            let c = iter_makespan_fused::<f32>(IterMethod::Cg, n, 100, 30, &p);
            assert!(c < s, "P={ranks}: {c} vs {s}");
        }
    }

    #[test]
    fn batched_twins_at_most_k_times_single_and_exact_at_k_1() {
        // Acceptance shape of BENCH_serving.json: on every modeled
        // configuration, batched <= k x single-RHS; strict for k > 1
        // (shared factorization / tiles / launches / latencies); and a
        // one-column batch prices bit-identically to the single-RHS model.
        let le = |b: f64, s: f64| b <= s * (1.0 + 1e-9);
        let n = 30_000usize;
        for ranks in [1usize, 2, 4, 8, 16] {
            for gpu in [false, true] {
                let p = params(ranks, gpu);
                // k = 1 degenerate batch: exact reproduction.
                assert_eq!(trsm_makespan::<f32>(n, 1, &p), trsv_makespan::<f32>(n, &p));
                assert_eq!(lu_solve_makespan_batched::<f32>(n, 1, &p), lu_makespan::<f32>(n, &p));
                assert_eq!(
                    chol_solve_makespan_batched::<f32>(n, 1, &p),
                    chol_makespan::<f32>(n, &p)
                );
                assert_eq!(
                    cg_makespan_batched::<f32>(n, 1, 100, &p),
                    iter_makespan::<f32>(IterMethod::Cg, n, 100, 30, &p)
                );
                assert_eq!(
                    bicgstab_makespan_batched::<f32>(n, 1, 100, &p),
                    iter_makespan::<f32>(IterMethod::Bicgstab, n, 100, 30, &p)
                );
                for k in [2usize, 4, 8, 16] {
                    let kf = k as f64;
                    let (tb, ts) =
                        (trsm_makespan::<f32>(n, k, &p), trsv_makespan::<f32>(n, &p));
                    assert!(le(tb, kf * ts), "trsm P={ranks} gpu={gpu} k={k}");
                    assert!(tb < kf * ts, "trsm must strictly amortize at k={k}");
                    let (lb, ls) =
                        (lu_solve_makespan_batched::<f32>(n, k, &p), lu_makespan::<f32>(n, &p));
                    assert!(lb < kf * ls, "LU batch must strictly win P={ranks} k={k}");
                    let (cb, cs) = (
                        chol_solve_makespan_batched::<f32>(n, k, &p),
                        chol_makespan::<f32>(n, &p),
                    );
                    assert!(cb < kf * cs, "Chol batch must strictly win P={ranks} k={k}");
                    let (gb, gs) = (
                        cg_makespan_batched::<f32>(n, k, 100, &p),
                        iter_makespan::<f32>(IterMethod::Cg, n, 100, 30, &p),
                    );
                    assert!(gb < kf * gs, "CG batch must strictly win P={ranks} k={k}");
                    let (bb, bs) = (
                        bicgstab_makespan_batched::<f32>(n, k, 100, &p),
                        iter_makespan::<f32>(IterMethod::Bicgstab, n, 100, 30, &p),
                    );
                    assert!(bb < kf * bs, "BiCGSTAB batch must strictly win P={ranks} k={k}");
                    // Direct methods amortize the whole factorisation: the
                    // batch must cost far less than k solves, approaching
                    // 1x as the solve phase vanishes next to the factor.
                    assert!(lb < 1.5 * ls, "k solves ride one LU factor: {lb} vs {ls}");
                }
            }
        }
        // The paper-scale acceptance point: dense solves at n = 60000,
        // f32, CUDA arm, 16 ranks — batching must pay there.
        let p = params(16, true);
        let k = 8usize;
        assert!(
            lu_solve_makespan_batched::<f32>(60_000, k, &p)
                < k as f64 * lu_makespan::<f32>(60_000, &p)
        );
        assert!(
            cg_makespan_batched::<f32>(60_000, k, 100, &p)
                < k as f64 * iter_makespan::<f32>(IterMethod::Cg, 60_000, 100, 30, &p)
        );
    }

    #[test]
    fn ckpt_overhead_is_exactly_the_legs_and_recovery_beats_recompute() {
        // Acceptance shape of BENCH_faults.json: (1) the fault-free
        // checkpointed twin exceeds its base by *exactly* the priced D2H
        // legs (equality by construction, asserted bit for bit); (2) with
        // the crash landing at or past the first checkpoint, checkpointed
        // recovery strictly undercuts recompute-from-scratch on every
        // configuration; (3) host profiles pay zero-byte legs yet still
        // win on the shorter replay.
        let n = 30_000usize;
        let every = 16usize;
        let reboot = 0.5f64;
        for ranks in [1usize, 2, 4, 8, 16] {
            for gpu in [false, true] {
                let p = params(ranks, gpu);
                let leg = ckpt_leg::<f32>(n, &p);
                assert_eq!(leg > 0.0, gpu, "legs are PCIe-only");
                let panels = n_panels(n, &p);
                let legs = n_checkpoints(panels, every) as f64 * leg;
                assert_eq!(
                    lu_makespan_ckpt::<f32>(n, every, &p),
                    lu_makespan_gpudirect::<f32>(n, &p) + legs,
                    "LU ckpt twin must be base + legs, bit for bit"
                );
                assert_eq!(
                    chol_makespan_ckpt::<f32>(n, every, &p),
                    chol_makespan_gpudirect::<f32>(n, &p) + legs,
                );
                for frac in [0.25f64, 0.5, 0.9] {
                    let crash = ((panels as f64 * frac) as usize).max(every);
                    let (cf, cc) = (
                        lu_recovery_full::<f32>(n, crash, reboot, &p),
                        lu_recovery_ckpt::<f32>(n, every, crash, reboot, &p),
                    );
                    assert!(cc < cf, "LU P={ranks} gpu={gpu} crash={crash}: {cc} vs {cf}");
                    let (hf, hc) = (
                        chol_recovery_full::<f32>(n, crash, reboot, &p),
                        chol_recovery_ckpt::<f32>(n, every, crash, reboot, &p),
                    );
                    assert!(hc < hf, "Chol P={ranks} gpu={gpu} crash={crash}: {hc} vs {hf}");
                }
                // Krylov: snapshot legs + bounded replay.
                let (iters, kevery) = (100usize, 10usize);
                for m in [IterMethod::Cg, IterMethod::Bicgstab, IterMethod::Gmres] {
                    let period = krylov_snap_period(m, kevery, 30);
                    let klegs =
                        n_checkpoints(iters, period) as f64 * krylov_snap_leg::<f32>(m, n, &p);
                    assert_eq!(
                        iter_makespan_ckpt::<f32>(m, n, iters, 30, kevery, &p),
                        iter_makespan_gpudirect::<f32>(m, n, iters, 30, &p) + klegs,
                    );
                    for frac in [0.25f64, 0.5, 0.9] {
                        let crash = ((iters as f64 * frac) as usize).max(period);
                        let f = iter_recovery_full::<f32>(m, n, iters, 30, crash, reboot, &p);
                        let c = iter_recovery_ckpt::<f32>(
                            m, n, iters, 30, kevery, crash, reboot, &p,
                        );
                        assert!(c < f, "{m:?} P={ranks} gpu={gpu} crash={crash}: {c} vs {f}");
                    }
                }
            }
        }
    }

    #[test]
    fn pipecg_model_trades_latency_for_vector_work() {
        // Blocking pipelined CG pays more local vector work than CG, but
        // its overlapped form beats blocking CG when latency dominates:
        // small n, many ranks, gigabit latency.
        let p = params(16, false);
        let n = 4_096usize;
        let nnz = 5 * n;
        let cg = sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &p);
        let pipe = sparse_pipecg_overlap_makespan::<f64>(n, nnz, 100, 0.9, &p);
        assert!(pipe < cg, "overlapped pipecg {pipe} must beat blocking CG {cg}");
    }

    #[test]
    fn sparse_cg_beats_dense_cg_by_orders_of_magnitude() {
        // A 1000x1000 grid: n = 1e6, nnz ~ 5e6 — the regime where the
        // sparse operand is the whole point of an iterative method.
        let g = 1_000usize;
        let n = g * g;
        let nnz = 5 * g * g - 4 * g;
        let sparse16 =
            sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &params(16, false));
        let dense16 = iter_makespan::<f64>(IterMethod::Cg, n, 100, 30, &params(16, false));
        assert!(
            sparse16 < dense16 / 100.0,
            "2·nnz flops must beat 2·n² by orders of magnitude: {sparse16} vs {dense16}"
        );
        // BiCG pays the extra transpose matvec + allreduce.
        let cg = sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &params(4, false));
        let bicg =
            sparse_iter_makespan::<f64>(IterMethod::Bicg, n, nnz, 100, 30, &params(4, false));
        assert!(bicg > cg);
    }

    #[test]
    fn sparse_scaling_is_compute_bound_only() {
        // Compute partitioning scales; but on Gigabit Ethernet the
        // halo-free full-vector allgather costs ~n bytes *regardless of
        // P*, so the network-inclusive makespan stops improving — the
        // honest flip side of the simple exchange (DESIGN.md §10).
        let g = 1_000usize;
        let (n, nnz) = (g * g, 5 * g * g - 4 * g);
        let ideal = |ranks: usize| ModelParams {
            net: NetworkModel::ideal(),
            ..params(ranks, false)
        };
        let t1 = sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &ideal(1));
        let t16 = sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &ideal(16));
        assert!(t16 < t1, "ideal network: more ranks must win ({t1} vs {t16})");
        assert!(t1 / t16 < 16.0, "sub-linear (replicated vector ops)");
        // And with the real network, the allgather term must actually cap
        // scaling: P=16 buys essentially nothing over P=4.
        let g4 = sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &params(4, false));
        let g16 = sparse_iter_makespan::<f64>(IterMethod::Cg, n, nnz, 100, 30, &params(16, false));
        assert!(
            g16 > 0.8 * g4,
            "gigabit: allgather (~n bytes regardless of P) must cap scaling: {g4} vs {g16}"
        );
    }
}
