//! Deterministic PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! The offline crate set has no `rand`, so workload generation and the
//! property-testing helper use this implementation.  Determinism matters
//! more than statistical extravagance here: every test failure must be
//! reproducible from its seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via SplitMix64 so that similar seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Prng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style multiply-shift; bias negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (one value per call, cached pair dropped
    /// for simplicity — generation is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with standard-normal values.
    pub fn fill_normal<S: crate::Scalar>(&mut self, out: &mut [S]) {
        for v in out.iter_mut() {
            *v = S::from_f64(self.normal()).unwrap();
        }
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            p.swap(i, self.below(i + 1));
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let u = p.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut p = Prng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| p.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut p = Prng::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = p.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut p = Prng::new(19);
        let perm = p.permutation(50);
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
