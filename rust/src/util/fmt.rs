//! Number / table formatting for bench reports (EXPERIMENTS.md output).

/// Format seconds with an adaptive unit (ns/µs/ms/s).
pub fn secs(s: f64) -> String {
    if !s.is_finite() {
        return format!("{s}");
    }
    let a = s.abs();
    if a >= 1.0 {
        format!("{s:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format a FLOP/s rate (K/M/G/T).
pub fn flops(f: f64) -> String {
    let a = f.abs();
    if a >= 1e12 {
        format!("{:.2} TFLOP/s", f / 1e12)
    } else if a >= 1e9 {
        format!("{:.2} GFLOP/s", f / 1e9)
    } else if a >= 1e6 {
        format!("{:.2} MFLOP/s", f / 1e6)
    } else {
        format!("{f:.0} FLOP/s")
    }
}

/// Format a byte count (KiB/MiB/GiB).
pub fn bytes(b: f64) -> String {
    let a = b.abs();
    if a >= (1u64 << 30) as f64 {
        format!("{:.2} GiB", b / (1u64 << 30) as f64)
    } else if a >= (1u64 << 20) as f64 {
        format!("{:.2} MiB", b / (1u64 << 20) as f64)
    } else if a >= 1024.0 {
        format!("{:.2} KiB", b / 1024.0)
    } else {
        format!("{b:.0} B")
    }
}

/// Render an aligned ASCII table: `header` then `rows`.
pub fn table(header: &[&str], rows: &[Vec<String>]) -> String {
    let cols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(cols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<String>, widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(header.iter().map(|s| s.to_string()).collect(), &widths));
    let mut sep = String::from("|");
    for w in &widths {
        sep.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    sep.push('\n');
    out.push_str(&sep);
    for row in rows {
        out.push_str(&fmt_row(row.clone(), &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn secs_units() {
        assert!(secs(2.5).contains("2.500 s"));
        assert!(secs(2.5e-3).contains("ms"));
        assert!(secs(2.5e-6).contains("µs"));
        assert!(secs(2.5e-9).contains("ns"));
    }

    #[test]
    fn flops_units() {
        assert!(flops(3.2e12).contains("TFLOP"));
        assert!(flops(3.2e9).contains("GFLOP"));
        assert!(flops(3.2e6).contains("MFLOP"));
    }

    #[test]
    fn bytes_units() {
        assert!(bytes(2.0 * 1024.0 * 1024.0 * 1024.0).contains("GiB"));
        assert!(bytes(2.0 * 1024.0 * 1024.0).contains("MiB"));
        assert!(bytes(2048.0).contains("KiB"));
        assert!(bytes(12.0).contains('B'));
    }

    #[test]
    fn table_aligns() {
        let t = table(
            &["p", "speedup"],
            &[vec!["1".into(), "1.00".into()], vec!["16".into(), "11.31".into()]],
        );
        assert!(t.contains("| p  | speedup |"));
        assert!(t.lines().count() == 4);
    }
}
