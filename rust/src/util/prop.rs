//! Minimal property-testing helper (no proptest in the offline crate set).
//!
//! `forall(cases, seed, f)` runs `f` against `cases` deterministic seeded
//! [`Prng`] streams and reports the failing case's seed so it can be replayed
//! verbatim (`replay(seed, f)`).  No shrinking — our generators take explicit
//! size parameters, so tests shrink by construction (start small).

use super::prng::Prng;

/// Run `f` over `cases` deterministic pseudo-random cases derived from
/// `seed`.  Panics with the case index + derived seed on first failure.
pub fn forall<F: FnMut(&mut Prng)>(cases: usize, seed: u64, mut f: F) {
    for case in 0..cases {
        let case_seed = seed ^ (0x9e37_79b9_7f4a_7c15u64.wrapping_mul(case as u64 + 1));
        let mut rng = Prng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(payload) = result {
            eprintln!(
                "property failed at case {case}/{cases} (replay with seed {case_seed:#x})"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Replay a single failing case by its derived seed.
pub fn replay<F: FnOnce(&mut Prng)>(case_seed: u64, f: F) {
    let mut rng = Prng::new(case_seed);
    f(&mut rng);
}

/// Pick one element of a slice.
pub fn choose<'a, T>(rng: &mut Prng, xs: &'a [T]) -> &'a T {
    &xs[rng.below(xs.len())]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(25, 1, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn forall_cases_differ() {
        let mut first = Vec::new();
        forall(10, 2, |rng| first.push(rng.next_u64()));
        assert_eq!(first.len(), 10);
        let unique: std::collections::HashSet<_> = first.iter().collect();
        assert_eq!(unique.len(), 10, "cases must use distinct streams");
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall(10, 3, |rng| assert!(rng.uniform() < 0.5));
    }

    #[test]
    fn choose_in_slice() {
        let xs = [1, 2, 3];
        let mut rng = Prng::new(5);
        for _ in 0..20 {
            assert!(xs.contains(choose(&mut rng, &xs)));
        }
    }
}
