//! Small in-tree utilities the offline crate set forces us to own:
//! a deterministic PRNG, a property-testing helper, wall-clock timers with
//! summary statistics, and number formatting for the bench reports.

pub mod fmt;
pub mod prng;
pub mod prop;
pub mod timer;

pub use prng::Prng;
pub use timer::{Stopwatch, TimerStats};
