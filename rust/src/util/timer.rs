//! Wall-clock timing utilities for the bench harness and metrics.

use std::time::Instant;

/// A simple stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    /// Seconds elapsed since `start`.
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Online summary statistics over a stream of samples (seconds).
#[derive(Clone, Debug, Default)]
pub struct TimerStats {
    n: usize,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl TimerStats {
    /// Empty stats.
    pub fn new() -> Self {
        TimerStats { n: 0, sum: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: 0.0 }
    }

    /// Record one sample.
    pub fn record(&mut self, secs: f64) {
        self.n += 1;
        self.sum += secs;
        self.sum_sq += secs * secs;
        self.min = self.min.min(secs);
        self.max = self.max.max(secs);
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.n
    }

    /// Total seconds.
    pub fn total(&self) -> f64 {
        self.sum
    }

    /// Mean seconds (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }

    /// Sample standard deviation (0 if < 2 samples).
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum_sq / self.n as f64 - m * m).max(0.0) * self.n as f64 / (self.n - 1) as f64)
            .sqrt()
    }

    /// Fastest sample (inf if empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Slowest sample.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another stats object into this one.
    pub fn merge(&mut self, other: &TimerStats) {
        self.n += other.n;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Run `f` `iters` times after `warmup` discarded runs; return stats.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> TimerStats {
    for _ in 0..warmup {
        f();
    }
    let mut stats = TimerStats::new();
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        stats.record(sw.secs());
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = TimerStats::new();
        for v in [1.0, 2.0, 3.0] {
            s.record(v);
        }
        assert_eq!(s.count(), 3);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        assert!((s.stddev() - 1.0).abs() < 1e-12);
        assert!((s.total() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn stats_merge() {
        let mut a = TimerStats::new();
        a.record(1.0);
        let mut b = TimerStats::new();
        b.record(3.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs() {
        let mut calls = 0;
        let s = bench(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.secs() >= 0.004);
    }
}
