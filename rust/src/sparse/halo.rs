//! The neighbor-exchange (halo) plan for row-block CSR operators —
//! `DESIGN.md` §15.
//!
//! PR 2's `pspmv` ships the **whole** padded vector through a column-comm
//! allgather per matvec: O(n) wire volume regardless of sparsity.  For the
//! operators the Krylov solvers actually see (PDE stencils, network
//! matrices), each rank's rows reference only a thin band of remote
//! columns — the *halo*.  A [`HaloPlan`] is the precomputed shape of that
//! band:
//!
//! * [`HaloPlan::ghost_cols`] — every remote-owned global column this
//!   rank's pattern touches, globally sorted.  The ghost buffer appends to
//!   the local vector block in exactly this order;
//! * [`HaloPlan::recv`] — `ghost_cols` partitioned by owning process row
//!   (what we need *from* each neighbor), with [`HaloPlan::recv_slots`]
//!   giving each list's positions in the ghost buffer;
//! * [`HaloPlan::send`] — what each neighbor needs from us, learned at
//!   build time through one split-phase all-pairs index handshake over the
//!   column communicator (a one-time O(pr²) exchange of `Ints` payloads,
//!   amortized over every subsequent matvec);
//! * [`HaloPlan::diag_local`] / [`HaloPlan::off_ghost`] — the row block's
//!   column split (same ownership test as [`super::SplitBlocks`]) with
//!   columns **renumbered** into the compact local / ghost coordinate
//!   spaces, so the halo matvec indexes two dense-packed small vectors
//!   instead of a padded full-length scratch.
//!
//! **Bit-identity invariant:** both renumberings are strictly monotone
//! (owned tiles keep their relative order under the block-cyclic
//! `local_ti` map; ghost slots follow the global sort), so each row's CSR
//! column order — and therefore the accumulation order of every floating
//! point sum — is *identical* to the allgather path's split halves.  The
//! halo `pspmv`/`pspmv_t` (see [`crate::pblas::pspmv_halo`]) reproduce the
//! allgather results bit for bit; only the wire volume changes, from O(n)
//! to O(surface).

use std::collections::BTreeSet;

use super::csr::CsrMatrix;
use super::dist_csr::DistCsrMatrix;
use crate::comm::{Group, NeighborExchange, Payload, Tag};
use crate::dist::Descriptor;
use crate::Scalar;

/// Compact local index of an **owned** global column `c` under the
/// block-cyclic vector layout: tile `c / tile` sits at local tile
/// `local_ti`, preserving global order among owned tiles (the monotonicity
/// the bit-identity contract rides on).
pub fn owned_local_col(desc: &Descriptor, c: usize) -> usize {
    let t = desc.tile;
    desc.local_ti(c / t) * t + c % t
}

/// One rank's halo-exchange plan (see the module docs).  Built once per
/// operator pattern via [`DistCsrMatrix::halo_plan`], invalidated by
/// [`DistCsrMatrix::local_mut`] exactly like the column split.
#[derive(Clone, Debug)]
pub struct HaloPlan<S: Scalar> {
    /// Locally-owned-column entries, columns renumbered to the compact
    /// local vector block (`ncols == ` this rank's padded block length).
    pub diag_local: CsrMatrix<S>,
    /// Remote-column entries, columns renumbered to ghost-buffer slots
    /// (`ncols == ghost_cols.len()`).
    pub off_ghost: CsrMatrix<S>,
    /// Every remote global column the pattern touches, sorted ascending.
    pub ghost_cols: Vec<usize>,
    /// Per process row: the sorted global columns we receive from it
    /// (`recv[own row]` is empty).
    pub recv: Vec<Vec<usize>>,
    /// Per process row: each `recv` list's slot positions in `ghost_cols`.
    pub recv_slots: Vec<Vec<usize>>,
    /// Per process row: the sorted global columns it receives from us
    /// (the handshake's answer; `send[own row]` is empty).
    pub send: Vec<Vec<usize>>,
}

impl<S: Scalar> HaloPlan<S> {
    /// Build the plan from `a`'s column structure.  `col` is the mesh's
    /// column communicator (group rank == process row); `tag` namespaces
    /// the one-time index handshake (callers pass
    /// `pblas::tags::HALO_PLAN`).  Collective over `col`: every member
    /// must call.
    pub fn build(a: &DistCsrMatrix<S>, col: &Group<'_, S>, tag: u32) -> Self {
        let desc = a.desc();
        let t = desc.tile;
        let pr = desc.shape.pr;
        let me = a.prow();
        assert_eq!(col.rank(), me, "column group rank must equal the process row");
        assert_eq!(col.size(), pr, "column group spans the process rows");
        let local = a.local();
        let width = local.nrows(); // square operator: local rows == local x elems

        // 1. Ghost columns: remote-owned, pattern-touched, globally sorted.
        let mut ghost_set = BTreeSet::new();
        for li in 0..local.nrows() {
            for &c in local.row(li).0 {
                if (c / t) % pr != me {
                    ghost_set.insert(c);
                }
            }
        }
        let ghost_cols: Vec<usize> = ghost_set.into_iter().collect();

        // 2. Partition by owning process row (order preserved => sorted).
        let mut recv: Vec<Vec<usize>> = vec![Vec::new(); pr];
        let mut recv_slots: Vec<Vec<usize>> = vec![Vec::new(); pr];
        for (slot, &c) in ghost_cols.iter().enumerate() {
            let owner = (c / t) % pr;
            recv[owner].push(c);
            recv_slots[owner].push(slot);
        }

        // 3. Handshake: tell each process row what we need from it; learn
        //    what it needs from us.  All pairs exchange exactly one `Ints`
        //    message (empty lists included) so matching is deterministic;
        //    receives post first, so the symmetric exchange cannot block.
        let mut send: Vec<Vec<usize>> = vec![Vec::new(); pr];
        if pr > 1 {
            let reqs: Vec<(usize, _)> = (0..pr)
                .filter(|&q| q != me)
                .map(|q| (q, col.irecv(q, Tag::P2p(tag))))
                .collect();
            let outs: Vec<_> = (0..pr)
                .filter(|&q| q != me)
                .map(|q| {
                    let wanted = recv[q].iter().map(|&c| c as i64).collect();
                    col.isend(q, Tag::P2p(tag), Payload::Ints(wanted))
                })
                .collect();
            for (q, req) in reqs {
                send[q] = req.wait().into_ints().into_iter().map(|c| c as usize).collect();
            }
            for s in outs {
                s.wait();
            }
        }

        // 4. The renumbered column split.  Both maps are monotone, so
        //    `from_rows`'s column sort reproduces the global-order CSR
        //    layout of the allgather path's halves entry for entry.
        let mut diag_rows: Vec<Vec<(usize, S)>> = Vec::with_capacity(local.nrows());
        let mut off_rows: Vec<Vec<(usize, S)>> = Vec::with_capacity(local.nrows());
        for li in 0..local.nrows() {
            let (cols, vals) = local.row(li);
            let (mut dr, mut or) = (Vec::new(), Vec::new());
            for (&c, &v) in cols.iter().zip(vals) {
                if (c / t) % pr == me {
                    dr.push((owned_local_col(desc, c), v));
                } else {
                    let slot = ghost_cols.binary_search(&c).expect("ghost col indexed");
                    or.push((slot, v));
                }
            }
            diag_rows.push(dr);
            off_rows.push(or);
        }
        HaloPlan {
            diag_local: CsrMatrix::from_rows(width, diag_rows),
            off_ghost: CsrMatrix::from_rows(ghost_cols.len(), off_rows),
            ghost_cols,
            recv,
            recv_slots,
            send,
        }
    }

    /// Ghost-buffer length — the elements received per forward matvec.
    pub fn ghost_elems(&self) -> usize {
        self.ghost_cols.len()
    }

    /// Elements shipped out per forward matvec (what the neighbors' ghost
    /// buffers need from us).
    pub fn send_elems(&self) -> usize {
        self.send.iter().map(Vec::len).sum()
    }

    /// Process rows we exchange with in either direction.
    pub fn neighbors(&self) -> usize {
        (0..self.recv.len())
            .filter(|&q| !self.recv[q].is_empty() || !self.send[q].is_empty())
            .count()
    }

    /// Gather the outgoing ghost segments from this rank's local vector
    /// block: one `(process row, values)` pair per nonempty send list.
    pub fn gather_sends(&self, desc: &Descriptor, xloc: &[S]) -> Vec<(usize, Vec<S>)> {
        self.send
            .iter()
            .enumerate()
            .filter(|(_, cols)| !cols.is_empty())
            .map(|(q, cols)| {
                (q, cols.iter().map(|&c| xloc[owned_local_col(desc, c)]).collect())
            })
            .collect()
    }

    /// The process rows we expect forward-halo segments from.
    pub fn recv_neighbors(&self) -> Vec<usize> {
        (0..self.recv.len()).filter(|&q| !self.recv[q].is_empty()).collect()
    }

    /// Run the plan's forward ghost exchange: returns the started
    /// [`NeighborExchange`]; scatter the received segments into a ghost
    /// buffer with [`HaloPlan::scatter_recv`].
    pub fn start_exchange<'a>(
        &self,
        col: &Group<'a, S>,
        tag: u32,
        desc: &Descriptor,
        xloc: &[S],
    ) -> NeighborExchange<'a, S> {
        NeighborExchange::start(
            col,
            tag,
            self.gather_sends(desc, xloc),
            &self.recv_neighbors(),
        )
    }

    /// [`HaloPlan::start_exchange`] over the GPUDirect wire: each outgoing
    /// ghost segment is handed to the NIC with `pcie_secs(bytes)` as its
    /// device-read leg, so under `cluster.gpudirect` the sparse interface
    /// bytes never touch the host.  A closure returning 0 (host engine,
    /// GPUDirect off) makes this exactly [`HaloPlan::start_exchange`].
    pub fn start_exchange_wire<'a>(
        &self,
        col: &Group<'a, S>,
        tag: u32,
        desc: &Descriptor,
        xloc: &[S],
        pcie_secs: impl Fn(usize) -> f64,
    ) -> NeighborExchange<'a, S> {
        let outgoing = self
            .gather_sends(desc, xloc)
            .into_iter()
            .map(|(q, seg)| {
                let leg = pcie_secs(seg.len() * S::BYTES);
                (q, seg, leg)
            })
            .collect();
        NeighborExchange::start_wire(col, tag, outgoing, &self.recv_neighbors())
    }

    /// Scatter completed forward-exchange segments into the ghost buffer
    /// (`xghost.len() == ghost_elems()`).
    pub fn scatter_recv(&self, received: &[(usize, Vec<S>)], xghost: &mut [S]) {
        for (q, seg) in received {
            let slots = &self.recv_slots[*q];
            assert_eq!(seg.len(), slots.len(), "ghost segment length mismatch");
            for (&slot, &v) in slots.iter().zip(seg.iter()) {
                xghost[slot] = v;
            }
        }
    }
}

/// A [`DistCsrMatrix`] routed through the halo-exchange matvecs: the same
/// operator, the same layout, but [`crate::pblas::LinOp::apply`] runs
/// [`crate::pblas::pspmv_halo`] (point-to-point ghost exchange) instead of
/// the allgather path.  Results are bit-identical by the plan's
/// monotone-renumbering contract; only the wire volume differs.
#[derive(Clone, Debug)]
pub struct HaloCsr<S: Scalar> {
    inner: DistCsrMatrix<S>,
}

impl<S: Scalar> HaloCsr<S> {
    /// Route `a` through the halo matvecs.
    pub fn new(a: DistCsrMatrix<S>) -> Self {
        HaloCsr { inner: a }
    }

    /// The wrapped operator.
    pub fn inner(&self) -> &DistCsrMatrix<S> {
        &self.inner
    }

    /// Mutable access (value edits invalidate the cached plan via
    /// [`DistCsrMatrix::local_mut`]).
    pub fn inner_mut(&mut self) -> &mut DistCsrMatrix<S> {
        &mut self.inner
    }

    /// Unwrap back to the allgather-routed operator.
    pub fn into_inner(self) -> DistCsrMatrix<S> {
        self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{NetworkModel, World};
    use crate::mesh::{Mesh, MeshShape};

    fn rows_of(m: usize) -> impl Fn(usize) -> Vec<(usize, f64)> + Clone + Send + Sync {
        move |i| {
            let mut r = vec![(i, 2.0 + i as f64)];
            if i + 3 < m {
                r.push((i + 3, -1.0));
            }
            if i >= 3 {
                r.push((i - 3, 0.5));
            }
            r
        }
    }

    #[test]
    fn serial_plan_has_no_ghosts_and_identity_renumbering() {
        let out = World::run::<f64, _, _>(1, NetworkModel::ideal(), |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(1, 1));
            let desc = crate::dist::Descriptor::new(11, 11, 4, mesh.shape());
            let a = DistCsrMatrix::from_row_fn(desc, 0, 0, rows_of(11));
            let plan = HaloPlan::build(&a, &mesh.col_comm(), 61);
            assert_eq!(plan.ghost_elems(), 0);
            assert_eq!(plan.send_elems(), 0);
            assert_eq!(plan.neighbors(), 0);
            assert_eq!(plan.off_ghost.nnz(), 0);
            // pr = 1: local_ti is the identity, so diag_local == local.
            assert_eq!(plan.diag_local.nnz(), a.local_nnz());
            for li in 0..a.local().nrows() {
                assert_eq!(plan.diag_local.row(li), a.local().row(li));
            }
            comm.stats().bytes_sent()
        });
        assert_eq!(out[0], 0, "a serial plan must never touch the wire");
    }

    #[test]
    fn plan_covers_exactly_the_off_block_columns_and_is_symmetric() {
        let (pr, m, t) = (3, 23, 4);
        let out = World::run::<f64, _, _>(pr, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, 1));
            let desc = crate::dist::Descriptor::new(m, m, t, mesh.shape());
            let a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), rows_of(m));
            let plan = HaloPlan::build(&a, &mesh.col_comm(), 61);
            // Ghosts == the distinct remote columns of the pattern.
            let mut want = std::collections::BTreeSet::new();
            for li in 0..a.local().nrows() {
                for &c in a.local().row(li).0 {
                    if (c / t) % pr != mesh.row() {
                        want.insert(c);
                    }
                }
            }
            assert_eq!(plan.ghost_cols, want.into_iter().collect::<Vec<_>>());
            // Split halves partition the block.
            assert_eq!(plan.diag_local.nnz() + plan.off_ghost.nnz(), a.local_nnz());
            (plan.recv.clone(), plan.send.clone())
        });
        // Symmetry across ranks: i's recv-from-j is j's send-to-i.
        for i in 0..pr {
            for j in 0..pr {
                assert_eq!(
                    out[i].0[j], out[j].1[i],
                    "recv[{i}<-{j}] must equal send[{j}->{i}]"
                );
            }
        }
    }
}
