//! Sparse operands (CUPLSS level 3, sparse side): CSR storage and its
//! row-block distribution.
//!
//! The paper's iterative solvers exist for systems too large for dense
//! direct methods — exactly the regime where the operator is usually
//! *sparse* (PDE stencils, circuit and network matrices).  This module
//! supplies that missing operand class:
//!
//! * [`CsrMatrix`] — one rank's (or a serial) compressed-sparse-row block:
//!   `row_ptr`/`col_idx`/`vals`, built from triplets or per-row entry lists
//!   with duplicate summing, with `spmv`/`spmv_t` kernels;
//! * [`DistCsrMatrix`] — the distributed operator: rows partitioned into
//!   the *same* tile row blocks as [`crate::dist::Descriptor`] (tile row
//!   `ti` on process row `ti mod pr`, replicated across process columns),
//!   so it composes with [`crate::dist::DistVector`] unchanged;
//! * [`HaloPlan`] / [`HaloCsr`] — the neighbor-exchange distribution over
//!   the same layout: per-neighbor send/recv index lists built from the
//!   column structure, ghost-cell storage appended to the local block, and
//!   a wrapper routing [`crate::pblas::LinOp`] through the point-to-point
//!   halo matvecs (`DESIGN.md` §15) — O(surface) wire volume per matvec,
//!   bit-identical results to the allgather path.
//!
//! Distributed matvecs live in [`crate::pblas::pspmv()`] /
//! [`crate::pblas::pspmv_t`]; the [`crate::pblas::LinOp`] trait lets every
//! Krylov solver consume dense and sparse operands through one interface.
//! Stencil generators (2-D/3-D Poisson) are in [`crate::workloads::stencil`].
//! See `DESIGN.md` §10 for the layout contract and the sparse cost model.

pub mod csr;
pub mod dist_csr;
pub mod halo;

pub use csr::CsrMatrix;
pub use dist_csr::{DistCsrMatrix, SplitBlocks};
pub use halo::{owned_local_col, HaloCsr, HaloPlan};
