//! The local compressed-sparse-row matrix: one rank's row block of a
//! sparse operator (or a whole serial operator).
//!
//! Classic three-array CSR: `row_ptr[i]..row_ptr[i+1]` indexes the stored
//! entries of row `i` in `col_idx`/`vals`.  The builders guarantee the
//! entries of every row are **sorted by column and unique** (duplicate
//! triplets are summed, the conventional assembly semantics for FEM/stencil
//! operators) — consumers such as [`CsrMatrix::diag`] rely on that order for
//! binary search.
//!
//! Unlike [`crate::dist::DistMatrix`] there is no identity padding: sparse
//! operands feed only matvec-based (Krylov) solvers, never factorisations,
//! so padded rows are simply *empty* and their matvec contributions vanish
//! against zero-padded vector blocks.

use crate::Scalar;

/// A sparse `nrows x ncols` matrix in compressed-sparse-row form.
#[derive(Clone, Debug)]
pub struct CsrMatrix<S: Scalar> {
    nrows: usize,
    ncols: usize,
    /// `nrows + 1` offsets into `col_idx`/`vals`.
    row_ptr: Vec<usize>,
    /// Column index of each stored entry (sorted within each row).
    col_idx: Vec<usize>,
    /// Value of each stored entry.
    vals: Vec<S>,
}

impl<S: Scalar> CsrMatrix<S> {
    /// Build from per-row entry lists `(col, val)`.  Rows may be unsorted
    /// and may contain duplicate columns; duplicates are **summed**.
    pub fn from_rows(ncols: usize, mut rows: Vec<Vec<(usize, S)>>) -> Self {
        let nrows = rows.len();
        let mut row_ptr = Vec::with_capacity(nrows + 1);
        row_ptr.push(0);
        let mut col_idx = Vec::new();
        let mut vals: Vec<S> = Vec::new();
        for row in &mut rows {
            row.sort_by_key(|&(c, _)| c);
            let mut last = usize::MAX;
            for &(c, v) in row.iter() {
                assert!(c < ncols, "column {c} outside 0..{ncols}");
                if c == last {
                    let k = vals.len() - 1;
                    vals[k] += v; // duplicate assembly entries sum
                } else {
                    col_idx.push(c);
                    vals.push(v);
                    last = c;
                }
            }
            row_ptr.push(col_idx.len());
        }
        CsrMatrix { nrows, ncols, row_ptr, col_idx, vals }
    }

    /// Build from a global triplet list `(row, col, val)` in any order;
    /// duplicate `(row, col)` entries are summed.
    pub fn from_triplets(nrows: usize, ncols: usize, triplets: &[(usize, usize, S)]) -> Self {
        let mut rows: Vec<Vec<(usize, S)>> = vec![Vec::new(); nrows];
        for &(r, c, v) in triplets {
            assert!(r < nrows, "row {r} outside 0..{nrows}");
            rows[r].push((c, v));
        }
        Self::from_rows(ncols, rows)
    }

    /// Stored rows.
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Stored columns.
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Stored entries (explicit zeros included).
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Row `i` as parallel `(columns, values)` slices, columns ascending.
    pub fn row(&self, i: usize) -> (&[usize], &[S]) {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[s..e], &self.vals[s..e])
    }

    /// Row `i` with mutable values (columns stay immutable: the sparsity
    /// pattern of a built matrix is fixed).
    pub fn row_mut(&mut self, i: usize) -> (&[usize], &mut [S]) {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[s..e], &mut self.vals[s..e])
    }

    /// The stored entry at `(i, j)` (`None` if the position is not stored —
    /// structurally zero).  Binary search over the row's sorted columns.
    pub fn get(&self, i: usize, j: usize) -> Option<S> {
        let (cols, vals) = self.row(i);
        cols.binary_search(&j).ok().map(|k| vals[k])
    }

    /// The stored diagonal entry of row `i` (`None` if structurally zero).
    pub fn diag(&self, i: usize) -> Option<S> {
        self.get(i, i)
    }

    /// `y = A x` (`x.len() == ncols`, `y.len() == nrows`, `y` overwritten).
    pub fn spmv(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.ncols, "spmv: x length != ncols");
        assert_eq!(y.len(), self.nrows, "spmv: y length != nrows");
        for i in 0..self.nrows {
            let mut acc = S::zero();
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += self.vals[k] * x[self.col_idx[k]];
            }
            y[i] = acc;
        }
    }

    /// `y = A^T x` (`x.len() == nrows`, `y.len() == ncols`, `y` overwritten).
    pub fn spmv_t(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.nrows, "spmv_t: x length != nrows");
        assert_eq!(y.len(), self.ncols, "spmv_t: y length != ncols");
        y.fill(S::zero());
        for i in 0..self.nrows {
            let xi = x[i];
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                y[self.col_idx[k]] += self.vals[k] * xi;
            }
        }
    }

    /// Densify (row-major `nrows x ncols`) — test/oracle helper.
    pub fn to_dense(&self) -> Vec<S> {
        let mut out = vec![S::zero(); self.nrows * self.ncols];
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                out[i * self.ncols + c] = v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triplets_roundtrip_with_duplicate_summing() {
        // (1,2) appears twice: 5 + 2.5 = 7.5; (0,0) twice: 1 - 1 = 0
        // (stored explicitly, still counted in nnz).
        let t = [
            (1usize, 2usize, 5.0f64),
            (0, 0, 1.0),
            (2, 1, -3.0),
            (1, 2, 2.5),
            (0, 0, -1.0),
            (1, 0, 4.0),
        ];
        let a = CsrMatrix::from_triplets(3, 3, &t);
        assert_eq!(a.nnz(), 4);
        let d = a.to_dense();
        let want = [0.0, 0.0, 0.0, 4.0, 0.0, 7.5, 0.0, -3.0, 0.0];
        assert_eq!(d, want);
    }

    #[test]
    fn rows_sorted_and_unique_after_build() {
        let a = CsrMatrix::from_rows(
            4,
            vec![vec![(3, 1.0f32), (0, 2.0), (3, 1.0)], vec![], vec![(2, 5.0)]],
        );
        let (cols, vals) = a.row(0);
        assert_eq!(cols, &[0, 3]);
        assert_eq!(vals, &[2.0, 2.0]);
        assert_eq!(a.row(1).0.len(), 0);
        assert_eq!(a.diag(2), Some(5.0));
        assert_eq!(a.diag(1), None);
    }

    #[test]
    fn spmv_and_transpose_match_dense() {
        let t = [
            (0usize, 0usize, 2.0f64),
            (0, 3, -1.0),
            (1, 1, 3.0),
            (2, 0, 1.0),
            (2, 2, 4.0),
            (2, 3, 0.5),
        ];
        let a = CsrMatrix::from_triplets(3, 4, &t);
        let dense = a.to_dense();
        let x4 = [1.0, -2.0, 0.5, 3.0];
        let mut y = vec![0.0; 3];
        a.spmv(&x4, &mut y);
        for i in 0..3 {
            let want: f64 = (0..4).map(|j| dense[i * 4 + j] * x4[j]).sum();
            assert!((y[i] - want).abs() < 1e-14, "row {i}");
        }
        let x3 = [2.0, 1.0, -1.0];
        let mut z = vec![9.0; 4]; // pre-filled: spmv_t must overwrite
        a.spmv_t(&x3, &mut z);
        for j in 0..4 {
            let want: f64 = (0..3).map(|i| dense[i * 4 + j] * x3[i]).sum();
            assert!((z[j] - want).abs() < 1e-14, "col {j}");
        }
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn out_of_range_triplet_panics() {
        let _ = CsrMatrix::from_triplets(2, 2, &[(0usize, 5usize, 1.0f64)]);
    }
}
