//! The distributed sparse matrix: row-block distributed CSR.
//!
//! Distribution rule: the matrix's *rows* follow exactly the
//! [`crate::dist::DistVector`] layout — tile row `ti` lives on process row
//! `ti mod pr` and is **replicated on every process column** of that row.
//! Each rank therefore stores one [`CsrMatrix`] holding its process row's
//! padded row blocks (`local_mt * tile` rows) over the *global* (padded)
//! column range.  Consequences:
//!
//! * a [`Descriptor`]-conformable [`crate::dist::DistVector`] composes
//!   unchanged — the same descriptor-equality validation the dense PBLAS
//!   performs applies verbatim;
//! * `y = A x` ([`crate::pblas::pspmv()`]) needs one column-comm allgather of
//!   the x blocks, then every owned row is computed *whole* (no partial
//!   sums, no row allreduce — rows are never split across ranks);
//! * `y = A^T x` ([`crate::pblas::pspmv_t`]) is local against the owned x
//!   blocks plus one column-comm allreduce of the full-length partials;
//! * the replicas on each process column compute identically, so results
//!   stay column-replicated like every vector in the crate.
//!
//! Padded rows (global index ≥ `m`) are empty (all-zero) rather than
//! identity-padded: sparse operands feed only the matvec-based Krylov
//! solvers, and zero rows times zero-padded vector blocks contribute
//! nothing.  See `DESIGN.md` §10.

use std::cell::{Ref, RefCell};

use super::csr::CsrMatrix;
use super::halo::HaloPlan;
use crate::comm::Group;
use crate::dist::Descriptor;
use crate::Scalar;

/// The column split of one rank's row block: the entries whose column tile
/// this process row also owns (so the matching `x` blocks are local) vs.
/// everything else.  This is the working set of the split-phase `pspmv`:
/// `diag` multiplies while the x allgather is in flight, `off` after it
/// completes (DESIGN.md §11).  Both halves span the full padded column
/// range; their stored entries are disjoint and union to the row block.
#[derive(Clone, Debug)]
pub struct SplitBlocks<S: Scalar> {
    /// Entries with locally-owned column tiles.
    pub diag: CsrMatrix<S>,
    /// Entries with remote column tiles.
    pub off: CsrMatrix<S>,
}

/// One rank's replica of a row-block-distributed CSR matrix.
#[derive(Clone, Debug)]
pub struct DistCsrMatrix<S: Scalar> {
    desc: Descriptor,
    prow: usize,
    pcol: usize,
    /// Owned padded row blocks (`desc.local_mt(prow) * desc.tile` rows)
    /// over `desc.padded_n()` global columns.
    local: CsrMatrix<S>,
    /// Lazily built column split for the split-phase matvec; invalidated
    /// by [`DistCsrMatrix::local_mut`] (value edits change both halves).
    split: RefCell<Option<SplitBlocks<S>>>,
    /// Lazily built halo-exchange plan for the neighbor-comm matvec;
    /// invalidated by [`DistCsrMatrix::local_mut`] like the split (the
    /// plan's compact CSR halves carry values, not just structure).
    halo: RefCell<Option<HaloPlan<S>>>,
}

impl<S: Scalar> DistCsrMatrix<S> {
    fn check_coords(desc: &Descriptor, prow: usize, pcol: usize) {
        assert!(
            desc.is_square(),
            "sparse operators are square (the Krylov solvers' domain), got {}x{}",
            desc.m,
            desc.n
        );
        assert!(
            prow < desc.shape.pr && pcol < desc.shape.pc,
            "coords ({prow},{pcol}) outside mesh {}x{}",
            desc.shape.pr,
            desc.shape.pc
        );
    }

    /// Build this rank's shard from a global row function: `row_of(i)`
    /// returns the nonzero `(col, val)` entries of global row `i < m`
    /// (any order; duplicates summed).  Every rank evaluates only its own
    /// rows — no data movement, mirroring [`crate::dist::DistMatrix::from_fn`].
    pub fn from_row_fn(
        desc: Descriptor,
        prow: usize,
        pcol: usize,
        row_of: impl Fn(usize) -> Vec<(usize, S)>,
    ) -> Self {
        Self::check_coords(&desc, prow, pcol);
        let t = desc.tile;
        let lmt = desc.local_mt(prow);
        let mut rows: Vec<Vec<(usize, S)>> = Vec::with_capacity(lmt * t);
        for l in 0..lmt {
            let ti = desc.global_ti(prow, l);
            for k in 0..t {
                let gi = ti * t + k;
                if gi < desc.m {
                    let r = row_of(gi);
                    // Hard assert (matching `from_triplets`): columns in
                    // [n, padded_n) would pass the CSR builder's bound but
                    // multiply against zero padding — a silent wrong answer.
                    assert!(
                        r.iter().all(|&(j, _)| j < desc.n),
                        "row {gi} references a column outside 0..{}",
                        desc.n
                    );
                    rows.push(r);
                } else {
                    rows.push(Vec::new()); // zero-padded row
                }
            }
        }
        let local = CsrMatrix::from_rows(desc.padded_n(), rows);
        DistCsrMatrix { desc, prow, pcol, local, split: RefCell::new(None), halo: RefCell::new(None) }
    }

    /// Build this rank's shard from a *global* triplet list: entries whose
    /// row this process row owns are kept (duplicates summed), the rest are
    /// ignored.  Every rank may pass the same full list.
    pub fn from_triplets(
        desc: Descriptor,
        prow: usize,
        pcol: usize,
        triplets: &[(usize, usize, S)],
    ) -> Self {
        Self::check_coords(&desc, prow, pcol);
        let t = desc.tile;
        let lmt = desc.local_mt(prow);
        let mut local_trip = Vec::new();
        for &(i, j, v) in triplets {
            assert!(i < desc.m && j < desc.n, "triplet ({i},{j}) outside {}x{}", desc.m, desc.n);
            let ti = i / t;
            if ti % desc.shape.pr == prow {
                local_trip.push((desc.local_ti(ti) * t + i % t, j, v));
            }
        }
        let local = CsrMatrix::from_triplets(lmt * t, desc.padded_n(), &local_trip);
        DistCsrMatrix { desc, prow, pcol, local, split: RefCell::new(None), halo: RefCell::new(None) }
    }

    /// The layout descriptor (shared with the vectors it pairs with).
    pub fn desc(&self) -> &Descriptor {
        &self.desc
    }

    /// This rank's process row.
    pub fn prow(&self) -> usize {
        self.prow
    }

    /// This rank's process column.
    pub fn pcol(&self) -> usize {
        self.pcol
    }

    /// The owned row block as a local CSR matrix (local row `l * tile + k`
    /// holds global row `desc.global_ti(prow, l) * tile + k`; columns are
    /// global).
    pub fn local(&self) -> &CsrMatrix<S> {
        &self.local
    }

    /// Mutable access to the owned row block (values only; the pattern of a
    /// built operator is fixed).  Invalidates the cached column split and
    /// the cached halo plan.
    pub fn local_mut(&mut self) -> &mut CsrMatrix<S> {
        *self.split.borrow_mut() = None;
        *self.halo.borrow_mut() = None;
        &mut self.local
    }

    /// The column split of the row block (built on first use, rebuilt after
    /// any [`DistCsrMatrix::local_mut`]): the split-phase `pspmv` runs one
    /// plain pass over each half instead of a masked double scan of every
    /// stored entry.
    pub fn split_blocks(&self) -> Ref<'_, SplitBlocks<S>> {
        if self.split.borrow().is_none() {
            let t = self.desc.tile;
            let pr = self.desc.shape.pr;
            let nrows = self.local.nrows();
            let mut diag: Vec<Vec<(usize, S)>> = Vec::with_capacity(nrows);
            let mut off: Vec<Vec<(usize, S)>> = Vec::with_capacity(nrows);
            for li in 0..nrows {
                let (cols, vals) = self.local.row(li);
                let (mut dr, mut or) = (Vec::new(), Vec::new());
                for (&c, &v) in cols.iter().zip(vals) {
                    if (c / t) % pr == self.prow {
                        dr.push((c, v));
                    } else {
                        or.push((c, v));
                    }
                }
                diag.push(dr);
                off.push(or);
            }
            *self.split.borrow_mut() = Some(SplitBlocks {
                diag: CsrMatrix::from_rows(self.desc.padded_n(), diag),
                off: CsrMatrix::from_rows(self.desc.padded_n(), off),
            });
        }
        Ref::map(self.split.borrow(), |o| o.as_ref().expect("split just built"))
    }

    /// The halo-exchange plan (built on first use through one collective
    /// index handshake over `col`, rebuilt after any
    /// [`DistCsrMatrix::local_mut`]).  `tag` namespaces the handshake
    /// (callers pass `pblas::tags::HALO_PLAN`).  First use is collective
    /// over the column communicator; cached uses are free and local.
    pub fn halo_plan(&self, col: &Group<'_, S>, tag: u32) -> Ref<'_, HaloPlan<S>> {
        if self.halo.borrow().is_none() {
            let plan = HaloPlan::build(self, col, tag);
            *self.halo.borrow_mut() = Some(plan);
        }
        Ref::map(self.halo.borrow(), |o| o.as_ref().expect("halo plan just built"))
    }

    /// Is a halo plan currently cached?  (Introspection for the
    /// invalidation tests — mirrors the split cache's lifecycle.)
    pub fn halo_is_cached(&self) -> bool {
        self.halo.borrow().is_some()
    }

    /// Stored entries on this rank.
    pub fn local_nnz(&self) -> usize {
        self.local.nnz()
    }

    /// Global row index held by local row `li`.
    pub fn global_row(&self, li: usize) -> usize {
        let t = self.desc.tile;
        self.desc.global_ti(self.prow, li / t) * t + li % t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::MeshShape;

    fn desc(m: usize, tile: usize, pr: usize, pc: usize) -> Descriptor {
        Descriptor::new(m, m, tile, MeshShape::new(pr, pc))
    }

    /// A small deterministic sparse pattern: diagonal + one off-diagonal
    /// band at distance 3.
    fn rows_of(m: usize) -> impl Fn(usize) -> Vec<(usize, f64)> + Clone {
        move |i| {
            let mut r = vec![(i, 2.0 + i as f64)];
            if i + 3 < m {
                r.push((i + 3, -1.0));
            }
            if i >= 3 {
                r.push((i - 3, 0.5));
            }
            r
        }
    }

    #[test]
    fn shards_jointly_cover_every_row_once() {
        let m = 11;
        let d = desc(m, 4, 3, 2);
        let mut seen = vec![0u32; m];
        for prow in 0..3 {
            // replicas across pcol must be identical
            let shards: Vec<DistCsrMatrix<f64>> =
                (0..2).map(|pcol| DistCsrMatrix::from_row_fn(d, prow, pcol, rows_of(m))).collect();
            for li in 0..shards[0].local().nrows() {
                assert_eq!(shards[0].local().row(li), shards[1].local().row(li));
                let gi = shards[0].global_row(li);
                if gi < m {
                    seen[gi] += 1;
                    let (cols, vals) = shards[0].local().row(li);
                    let want = {
                        let mut w = rows_of(m)(gi);
                        w.sort_by_key(|&(c, _)| c);
                        w
                    };
                    assert_eq!(cols.len(), want.len());
                    for (k, &(c, v)) in want.iter().enumerate() {
                        assert_eq!(cols[k], c);
                        assert_eq!(vals[k], v);
                    }
                } else {
                    assert_eq!(shards[0].local().row(li).0.len(), 0, "pad rows are empty");
                }
            }
        }
        assert!(seen.iter().all(|&k| k == 1), "each row owned exactly once: {seen:?}");
    }

    #[test]
    fn from_triplets_matches_from_row_fn() {
        let m = 10;
        let d = desc(m, 4, 2, 2);
        let mut trip = Vec::new();
        for i in 0..m {
            for (j, v) in rows_of(m)(i) {
                trip.push((i, j, v));
            }
        }
        for prow in 0..2 {
            let a = DistCsrMatrix::from_triplets(d, prow, 0, &trip);
            let b = DistCsrMatrix::from_row_fn(d, prow, 0, rows_of(m));
            assert_eq!(a.local_nnz(), b.local_nnz());
            for li in 0..a.local().nrows() {
                assert_eq!(a.local().row(li), b.local().row(li), "prow {prow} row {li}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "square")]
    fn rectangular_descriptor_rejected() {
        let d = Descriptor::new(8, 6, 2, MeshShape::new(1, 1));
        let _ = DistCsrMatrix::<f64>::from_row_fn(d, 0, 0, |_| Vec::new());
    }

    #[test]
    fn split_blocks_partition_the_row_block_and_track_mutation() {
        let m = 11;
        let d = desc(m, 4, 3, 1);
        for prow in 0..3 {
            let mut a = DistCsrMatrix::from_row_fn(d, prow, 0, rows_of(m));
            {
                let s = a.split_blocks();
                // Disjoint by column-tile ownership, jointly the whole block.
                assert_eq!(s.diag.nnz() + s.off.nnz(), a.local_nnz());
                for li in 0..a.local().nrows() {
                    for (&c, &v) in s.diag.row(li).0.iter().zip(s.diag.row(li).1) {
                        assert_eq!((c / 4) % 3, prow, "diag col {c} must be owned");
                        assert_eq!(a.local().get(li, c), Some(v));
                    }
                    for &c in s.off.row(li).0 {
                        assert_ne!((c / 4) % 3, prow, "off col {c} must be remote");
                    }
                }
            }
            // Value edits invalidate the cached split.
            let before = a.split_blocks().diag.nnz();
            let li = (0..a.local().nrows()).find(|&li| !a.local().row(li).0.is_empty()).unwrap();
            {
                let (_, vals) = a.local_mut().row_mut(li);
                vals[0] *= 2.0;
            }
            let s = a.split_blocks();
            assert_eq!(s.diag.nnz(), before, "pattern unchanged");
            let c = a.local().row(li).0[0];
            let v = a.local().row(li).1[0];
            let in_split = if (c / 4) % 3 == prow { s.diag.get(li, c) } else { s.off.get(li, c) };
            assert_eq!(in_split, Some(v), "rebuilt split sees the new value");
        }
    }
}
