//! Bench: blocking vs overlapped makespans — the printed number behind the
//! split-phase refactor (DESIGN.md §11).
//!
//! For every paper rank count and both engine arms on the gigabit network,
//! evaluates the analytic model in its blocking and overlapped schedules
//! for the three refactored hot paths:
//!
//! * **LU** — classic right-looking vs depth-1 lookahead;
//! * **SUMMA** — one panel in flight vs double-buffered;
//! * **sparse CG / pipelined CG** — blocking exchanges vs split-phase
//!   `pspmv` and the matvec-overlapped fused reduction.
//!
//! Emits `BENCH_overlap.json` and asserts the acceptance shape: overlapped
//! `<=` blocking on *every* configuration, strictly smaller for LU
//! lookahead and pipelined CG wherever there is latency to hide.
//!
//! ```sh
//! cargo bench --bench overlap
//! ```

use cuplss::accel::ComputeProfile;
use cuplss::bench_harness::model::{
    lu_makespan, lu_makespan_lookahead, sparse_cg_split_makespan, sparse_iter_makespan,
    sparse_pipecg_overlap_makespan, summa_makespan,
};
use cuplss::bench_harness::{ModelParams, PAPER_N, PAPER_RANKS};
use cuplss::comm::NetworkModel;
use cuplss::mesh::MeshShape;
use cuplss::solvers::IterMethod;
use cuplss::util::fmt;

/// Diagonal-block nnz fraction of the 5-point stencil row blocks (bandwidth
/// << block rows, so nearly every entry's column is locally owned).
const STENCIL_DIAG_FRAC: f64 = 0.9;

struct Row {
    kernel: &'static str,
    engine: &'static str,
    n: usize,
    ranks: usize,
    blocking: f64,
    overlapped: f64,
}

fn params(ranks: usize, gpu: bool) -> ModelParams {
    ModelParams {
        tile: 256,
        shape: MeshShape::near_square(ranks),
        net: NetworkModel::gigabit_ethernet(),
        engine: if gpu {
            ComputeProfile::gtx280_cublas()
        } else {
            ComputeProfile::q6600_atlas()
        },
        panel_cpu: ComputeProfile::q6600_atlas(),
        swap_fraction: 0.5,
        device_mem: cuplss::accel::DEFAULT_DEVICE_MEM,
    }
}

fn main() {
    let grid = 1_000usize;
    let (sparse_n, nnz) = (grid * grid, 5 * grid * grid - 4 * grid);
    let iters = 100usize;
    let mut rows: Vec<Row> = Vec::new();

    for &ranks in PAPER_RANKS {
        for gpu in [false, true] {
            let p = params(ranks, gpu);
            let engine = if gpu { "MPI+CUDA" } else { "MPI+ATLAS" };
            rows.push(Row {
                kernel: "LU",
                engine,
                n: PAPER_N,
                ranks,
                blocking: lu_makespan::<f32>(PAPER_N, &p),
                overlapped: lu_makespan_lookahead::<f32>(PAPER_N, &p),
            });
            rows.push(Row {
                kernel: "SUMMA",
                engine,
                n: PAPER_N,
                ranks,
                blocking: summa_makespan::<f32>(PAPER_N, &p, false),
                overlapped: summa_makespan::<f32>(PAPER_N, &p, true),
            });
            if !gpu {
                // Sparse operands run on the CPU arm only (no AOT kernel).
                rows.push(Row {
                    kernel: "sparse CG",
                    engine,
                    n: sparse_n,
                    ranks,
                    blocking: sparse_iter_makespan::<f64>(
                        IterMethod::Cg,
                        sparse_n,
                        nnz,
                        iters,
                        30,
                        &p,
                    ),
                    overlapped: sparse_cg_split_makespan::<f64>(
                        sparse_n,
                        nnz,
                        iters,
                        STENCIL_DIAG_FRAC,
                        &p,
                    ),
                });
                rows.push(Row {
                    kernel: "pipelined CG",
                    engine,
                    n: sparse_n,
                    ranks,
                    blocking: sparse_iter_makespan::<f64>(
                        IterMethod::PipeCg,
                        sparse_n,
                        nnz,
                        iters,
                        30,
                        &p,
                    ),
                    overlapped: sparse_pipecg_overlap_makespan::<f64>(
                        sparse_n,
                        nnz,
                        iters,
                        STENCIL_DIAG_FRAC,
                        &p,
                    ),
                });
            }
        }
    }

    // Table for the terminal.
    let header = ["kernel", "engine", "P", "blocking", "overlapped", "hidden"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kernel.to_string(),
                r.engine.to_string(),
                r.ranks.to_string(),
                fmt::secs(r.blocking),
                fmt::secs(r.overlapped),
                format!("{:.1}%", (1.0 - r.overlapped / r.blocking) * 100.0),
            ]
        })
        .collect();
    println!("== Blocking vs overlapped makespans (gigabit ethernet) ==");
    println!("{}", fmt::table(&header, &body));

    // Acceptance shape.
    for r in &rows {
        assert!(
            // Relative slack: P=1 rows sum identical terms in different
            // association orders and agree only to round-off.
            r.overlapped <= r.blocking * (1.0 + 1e-9),
            "{} {} P={}: overlapped {} > blocking {}",
            r.kernel,
            r.engine,
            r.ranks,
            r.overlapped,
            r.blocking
        );
        let must_be_strict = match r.kernel {
            // Overlap hides *network* legs; on one rank there is nothing to
            // hide (the host getrf stays on the single compute timeline).
            "LU" => r.ranks > 1,
            "pipelined CG" => MeshShape::near_square(r.ranks).pr > 1,
            _ => false,
        };
        if must_be_strict {
            assert!(
                r.overlapped < r.blocking,
                "{} {} P={}: overlap must strictly win",
                r.kernel,
                r.engine,
                r.ranks
            );
        }
    }

    // BENCH_overlap.json (hand-rolled: the offline crate set has no serde).
    let mut json = String::from("{\n  \"network\": \"gigabit_ethernet\",\n  \"entries\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"engine\": \"{}\", \"n\": {}, \"ranks\": {}, \
             \"blocking_secs\": {:.6e}, \"overlapped_secs\": {:.6e}, \"hidden_frac\": {:.4}}}{}\n",
            r.kernel,
            r.engine,
            r.n,
            r.ranks,
            r.blocking,
            r.overlapped,
            1.0 - r.overlapped / r.blocking,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_overlap.json", &json).expect("write BENCH_overlap.json");
    println!("wrote BENCH_overlap.json ({} entries); overlap never loses.", rows.len());
}
