//! Bench: GPUDirect device-to-NIC sends vs the host-staged barrier — the
//! printed number behind the wire subsystem (`DESIGN.md` §16).
//!
//! For every paper rank count and both engine arms, evaluates the analytic
//! model in two arms that differ **only** in how device-dirty send
//! payloads reach the NIC:
//!
//! * **host-staged** — every send flushes the dirty device buffer D2H
//!   first (`Ctx::host_read` at the send site), serialising the staging
//!   PCIe ahead of the NIC: the copy-engine prefetch twin plus the
//!   per-kernel `*_wire_stage` term;
//! * **gpudirect** — the dirty buffer goes straight to the NIC
//!   (`Ctx::wire_read`), the PCIe leg riding *under* the send's own NIC
//!   occupancy on the joint timeline (`VClock::wire_occupy_from`): the
//!   `*_makespan_gpudirect` twin.
//!
//! Dense rows cover LU, Cholesky, SUMMA and CG/BiCGSTAB; sparse rows run
//! the Poisson stencils through the fused sparse twins — host-arm
//! operands, host-clean ghost segments, so the halo wire composes with
//! GPUDirect as an exact wash (asserted, not papered over).  Likewise
//! SUMMA: its broadcast panels are read-only and host-clean, an exact
//! wash on both arms.
//!
//! Emits `BENCH_gpudirect.json` and asserts the acceptance shape:
//! gpudirect <= host-staged on every configuration, strictly smaller
//! exactly where a device-dirty payload hits the wire (`wire_stage > 0`:
//! the accelerated arm with real column/row sends), and an exact wash on
//! host profiles and for host-clean payloads.
//!
//! ```sh
//! cargo bench --bench gpudirect
//! ```

use cuplss::accel::{ComputeProfile, DEFAULT_DEVICE_MEM};
use cuplss::bench_harness::model::{
    chol_makespan_gpudirect, chol_makespan_prefetch, chol_wire_stage, iter_makespan_gpudirect,
    iter_makespan_prefetch, iter_wire_stage, lu_makespan_gpudirect, lu_makespan_prefetch,
    lu_wire_stage, sparse_iter_makespan_gpudirect, sparse_iter_makespan_prefetch,
    sparse_iter_wire_stage, summa_makespan_gpudirect, summa_makespan_prefetch, summa_wire_stage,
};
use cuplss::bench_harness::{ModelParams, PAPER_N, PAPER_RANKS};
use cuplss::comm::NetworkModel;
use cuplss::mesh::MeshShape;
use cuplss::solvers::IterMethod;
use cuplss::util::fmt;
use cuplss::workloads::stencil_halo_counts;

struct Row {
    kernel: &'static str,
    engine: &'static str,
    n: usize,
    ranks: usize,
    pr: usize,
    pc: usize,
    wire_stage: f64,
    staged: f64,
    gpudirect: f64,
    /// Must GPUDirect win strictly (a device-dirty payload hit the wire)?
    strict: bool,
}

struct SparseRow {
    stencil: &'static str,
    method: &'static str,
    grid: usize,
    n: usize,
    nnz: usize,
    ranks: usize,
    staged: f64,
    gpudirect: f64,
}

fn params(ranks: usize, gpu: bool) -> ModelParams {
    ModelParams {
        tile: 256,
        shape: MeshShape::near_square(ranks),
        net: NetworkModel::gigabit_ethernet(),
        engine: if gpu {
            ComputeProfile::gtx280_cublas()
        } else {
            ComputeProfile::q6600_atlas()
        },
        panel_cpu: ComputeProfile::q6600_atlas(),
        swap_fraction: 0.5,
        device_mem: DEFAULT_DEVICE_MEM,
    }
}

fn main() {
    let iters = 100usize;
    let summa_n = 16_384usize;
    let mut rows: Vec<Row> = Vec::new();

    for &ranks in PAPER_RANKS {
        for gpu in [false, true] {
            let p = params(ranks, gpu);
            let (pr, pc) = (p.shape.pr, p.shape.pc);
            let engine = if gpu { "MPI+CUDA" } else { "MPI+ATLAS" };
            let mut push = |kernel, n, stage: f64, prefetch: f64, gpudirect: f64| {
                rows.push(Row {
                    kernel,
                    engine,
                    n,
                    ranks,
                    pr,
                    pc,
                    wire_stage: stage,
                    staged: prefetch + stage,
                    gpudirect,
                    strict: stage > 0.0,
                });
            };
            push(
                "LU",
                PAPER_N,
                lu_wire_stage::<f32>(PAPER_N, &p),
                lu_makespan_prefetch::<f32>(PAPER_N, &p),
                lu_makespan_gpudirect::<f32>(PAPER_N, &p),
            );
            push(
                "Cholesky",
                PAPER_N,
                chol_wire_stage::<f32>(PAPER_N, &p),
                chol_makespan_prefetch::<f32>(PAPER_N, &p),
                chol_makespan_gpudirect::<f32>(PAPER_N, &p),
            );
            push(
                "SUMMA",
                summa_n,
                summa_wire_stage::<f32>(summa_n, &p),
                summa_makespan_prefetch::<f32>(summa_n, &p, true),
                summa_makespan_gpudirect::<f32>(summa_n, &p, true),
            );
            for (m, name) in [(IterMethod::Cg, "CG"), (IterMethod::Bicgstab, "BiCGSTAB")] {
                push(
                    name,
                    PAPER_N,
                    iter_wire_stage::<f32>(m, PAPER_N, iters, &p),
                    iter_makespan_prefetch::<f32>(m, PAPER_N, iters, 30, &p),
                    iter_makespan_gpudirect::<f32>(m, PAPER_N, iters, 30, &p),
                );
            }
        }
    }

    // Halo-sparse configs: host-arm operands, host-clean ghost segments —
    // the wire stage is zero and GPUDirect must be an exact wash.
    let mut sparse_rows: Vec<SparseRow> = Vec::new();
    for &ranks in PAPER_RANKS {
        let p = params(ranks, false);
        for (stencil, grid, dim) in [("poisson2d", 512usize, 2u32), ("poisson3d", 64, 3)] {
            let n = grid.pow(dim);
            let h = stencil_halo_counts(grid, dim, p.tile, p.shape.pr);
            for (m, name) in [(IterMethod::Cg, "CG"), (IterMethod::Bicgstab, "BiCGSTAB")] {
                let prefetch =
                    sparse_iter_makespan_prefetch::<f64>(m, n, h.total_nnz, iters, 30, &p);
                sparse_rows.push(SparseRow {
                    stencil,
                    method: name,
                    grid,
                    n,
                    nnz: h.total_nnz,
                    ranks,
                    staged: prefetch + sparse_iter_wire_stage::<f64>(n, h.total_nnz, &p),
                    gpudirect: sparse_iter_makespan_gpudirect::<f64>(
                        m,
                        n,
                        h.total_nnz,
                        iters,
                        30,
                        &p,
                    ),
                });
            }
        }
    }

    // Table for the terminal.
    let header = ["kernel", "engine", "P", "stage", "host-staged", "gpudirect", "saved"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kernel.to_string(),
                r.engine.to_string(),
                r.ranks.to_string(),
                fmt::secs(r.wire_stage),
                fmt::secs(r.staged),
                fmt::secs(r.gpudirect),
                format!("{:.1}%", (1.0 - r.gpudirect / r.staged) * 100.0),
            ]
        })
        .collect();
    println!("== GPUDirect wire vs host-staged sends ==");
    println!("{}", fmt::table(&header, &body));

    // Acceptance shape.
    for r in &rows {
        assert!(
            r.gpudirect <= r.staged * (1.0 + 1e-9),
            "{} {} P={}: gpudirect {} > host-staged {}",
            r.kernel,
            r.engine,
            r.ranks,
            r.gpudirect,
            r.staged
        );
        if r.strict {
            assert!(
                r.gpudirect < r.staged,
                "{} {} P={}: a device-dirty payload hit the wire, gpudirect must strictly win",
                r.kernel,
                r.engine,
                r.ranks
            );
        } else {
            assert!(
                (r.gpudirect - r.staged).abs() <= 1e-12 * r.staged.max(1.0),
                "{} {} P={}: no dirty payload on the wire must be an exact wash",
                r.kernel,
                r.engine,
                r.ranks
            );
        }
    }
    for r in &sparse_rows {
        assert!(
            (r.gpudirect - r.staged).abs() <= 1e-12 * r.staged.max(1.0),
            "{} {} P={}: host-clean ghost payloads must be an exact wash",
            r.stencil,
            r.method,
            r.ranks
        );
    }

    // BENCH_gpudirect.json (hand-rolled: the offline crate set has no serde).
    let mut json = format!(
        "{{\n  \"network\": \"gigabit_ethernet\",\n  \"tile\": 256,\n  \"iters\": {iters},\n  \"entries\": [\n"
    );
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"engine\": \"{}\", \"n\": {}, \"ranks\": {}, \
             \"pr\": {}, \"pc\": {}, \"wire_stage_secs\": {:.6e}, \"staged_secs\": {:.6e}, \
             \"gpudirect_secs\": {:.6e}, \"saved_frac\": {:.4}, \"strict\": {}}}{}\n",
            r.kernel,
            r.engine,
            r.n,
            r.ranks,
            r.pr,
            r.pc,
            r.wire_stage,
            r.staged,
            r.gpudirect,
            1.0 - r.gpudirect / r.staged,
            r.strict,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"sparse\": [\n");
    for (i, r) in sparse_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"stencil\": \"{}\", \"method\": \"{}\", \"grid\": {}, \"n\": {}, \
             \"nnz\": {}, \"ranks\": {}, \"staged_secs\": {:.6e}, \
             \"gpudirect_secs\": {:.6e}}}{}\n",
            r.stencil,
            r.method,
            r.grid,
            r.n,
            r.nnz,
            r.ranks,
            r.staged,
            r.gpudirect,
            if i + 1 < sparse_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_gpudirect.json", &json).expect("write BENCH_gpudirect.json");
    println!(
        "wrote BENCH_gpudirect.json ({} dense + {} sparse rows); the wire never loses.",
        rows.len(),
        sparse_rows.len()
    );
}
