//! Bench E8 — model-vs-live calibration: run the *actual* distributed
//! solvers (real messages, real tile ops, live virtual clock) at small n and
//! compare against the analytic model that generates the n = 60000 figures.
//!
//! ```sh
//! cargo bench --bench calibration
//! ```
//!
//! Acceptance: model within 2x of live everywhere (the model's job is the
//! *shape* of the speedup curves; a constant factor cancels in the ratio).

use cuplss::bench_harness::calibrate::{calibrate, max_ratio_error, render};
use cuplss::cluster::Method;
use cuplss::solvers::IterMethod;
use cuplss::workloads::Workload;

fn main() {
    let sizes = [256usize, 512, 1024];
    let ranks = [1usize, 4, 16];

    println!("== E8: live vs model, LU on DiagDominant (f64, tile 64) ==");
    let lu = calibrate(Method::Lu, Workload::DiagDominant, &sizes, &ranks, 64)
        .expect("lu calibration");
    println!("{}", render(&lu));
    let lu_err = max_ratio_error(&lu);
    println!("max ratio error: {lu_err:.2}x\n");

    println!("== E8: live vs model, BiCGSTAB on DiagDominant (f64, tile 64) ==");
    let it = calibrate(
        Method::Iterative(IterMethod::Bicgstab),
        Workload::DiagDominant,
        &sizes,
        &ranks,
        64,
    )
    .expect("bicgstab calibration");
    println!("{}", render(&it));
    let it_err = max_ratio_error(&it);
    println!("max ratio error: {it_err:.2}x\n");

    assert!(lu_err < 2.0, "LU model out of band: {lu_err}");
    assert!(it_err < 2.0, "BiCGSTAB model out of band: {it_err}");
    println!("E8 passed: analytic model within {:.2}x of live runs.", lu_err.max(it_err));
}
