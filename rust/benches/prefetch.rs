//! Bench: synchronous residency accounting vs the copy-engine timeline —
//! the printed number behind the async prefetch / write-back subsystem
//! (`DESIGN.md` §13).
//!
//! For every paper rank count and both engine arms on the gigabit network,
//! evaluates the analytic model in three flows for each refactored hot
//! path: **streaming** (the paper's §3 copy-per-call), **resident**
//! (PR 4's tile cache, surviving transfers on the compute timeline) and
//! **prefetch** (the same transfers moved to the copy-engine timeline,
//! hidden under compute):
//!
//! * **LU / Cholesky** — the trailing sweep's panel first-touch and C-tile
//!   streams ride under the gemm stream;
//! * **SUMMA** — panel H2D under the `gemm_acc` sweep;
//! * **CG / pipelined CG / BiCGSTAB** — x first-touch + the (now
//!   device-resident) matvec output's single write-back under the gemv
//!   sweep, or the full thrash re-streams when the budget forces eviction;
//!   the sparse rows pin the degenerate case (host-side operands, copy
//!   engine idle: prefetch == resident by definition).
//!
//! Emits `BENCH_prefetch.json` and asserts the acceptance shape:
//! `prefetch <= resident <= streaming` on *every* configuration, prefetch
//! strictly smaller wherever residency still paid PCIe on the compute
//! timeline (the accelerated arm), and exactly equal on host profiles.
//!
//! ```sh
//! cargo bench --bench prefetch
//! ```

use cuplss::accel::{ComputeProfile, DEFAULT_DEVICE_MEM};
use cuplss::bench_harness::model::{
    chol_makespan, chol_makespan_prefetch, chol_makespan_resident, iter_makespan,
    iter_makespan_fused, iter_makespan_prefetch, lu_makespan_lookahead, lu_makespan_prefetch,
    lu_makespan_resident, lu_prefetch_headroom, sparse_iter_makespan,
    sparse_iter_makespan_fused, sparse_iter_makespan_prefetch, summa_makespan,
    summa_makespan_prefetch, summa_makespan_resident,
};
use cuplss::bench_harness::{ModelParams, PAPER_N, PAPER_RANKS};
use cuplss::comm::NetworkModel;
use cuplss::mesh::MeshShape;
use cuplss::solvers::IterMethod;
use cuplss::util::fmt;

struct Row {
    kernel: &'static str,
    engine: &'static str,
    n: usize,
    ranks: usize,
    streaming: f64,
    resident: f64,
    prefetch: f64,
    /// Must prefetch win strictly over resident (PCIe on the compute path)?
    strict: bool,
}

fn params(ranks: usize, gpu: bool) -> ModelParams {
    ModelParams {
        tile: 256,
        shape: MeshShape::near_square(ranks),
        net: NetworkModel::gigabit_ethernet(),
        engine: if gpu {
            ComputeProfile::gtx280_cublas()
        } else {
            ComputeProfile::q6600_atlas()
        },
        panel_cpu: ComputeProfile::q6600_atlas(),
        swap_fraction: 0.5,
        device_mem: DEFAULT_DEVICE_MEM,
    }
}

fn main() {
    let grid = 1_000usize;
    let (sparse_n, nnz) = (grid * grid, 5 * grid * grid - 4 * grid);
    let iters = 100usize;
    let mut rows: Vec<Row> = Vec::new();

    for &ranks in PAPER_RANKS {
        for gpu in [false, true] {
            let p = params(ranks, gpu);
            let engine = if gpu { "MPI+CUDA" } else { "MPI+ATLAS" };
            rows.push(Row {
                kernel: "LU",
                engine,
                n: PAPER_N,
                ranks,
                streaming: lu_makespan_lookahead::<f32>(PAPER_N, &p),
                resident: lu_makespan_resident::<f32>(PAPER_N, &p),
                prefetch: lu_makespan_prefetch::<f32>(PAPER_N, &p),
                // Strict only where residency left PCIe on the critical
                // path — the comm lookahead already hides the trailing leg
                // outright at large rank counts.
                strict: gpu && lu_prefetch_headroom::<f32>(PAPER_N, &p),
            });
            rows.push(Row {
                kernel: "Cholesky",
                engine,
                n: PAPER_N,
                ranks,
                streaming: chol_makespan::<f32>(PAPER_N, &p),
                resident: chol_makespan_resident::<f32>(PAPER_N, &p),
                prefetch: chol_makespan_prefetch::<f32>(PAPER_N, &p),
                strict: gpu,
            });
            rows.push(Row {
                kernel: "SUMMA",
                engine,
                n: PAPER_N,
                ranks,
                streaming: summa_makespan::<f32>(PAPER_N, &p, true),
                resident: summa_makespan_resident::<f32>(PAPER_N, &p, true),
                prefetch: summa_makespan_prefetch::<f32>(PAPER_N, &p, true),
                strict: gpu,
            });
            for (m, name) in [
                (IterMethod::Cg, "CG"),
                (IterMethod::PipeCg, "pipelined CG"),
                (IterMethod::Bicgstab, "BiCGSTAB"),
            ] {
                rows.push(Row {
                    kernel: name,
                    engine,
                    n: PAPER_N,
                    ranks,
                    streaming: iter_makespan::<f32>(m, PAPER_N, iters, 30, &p),
                    resident: iter_makespan_fused::<f32>(m, PAPER_N, iters, 30, &p),
                    prefetch: iter_makespan_prefetch::<f32>(m, PAPER_N, iters, 30, &p),
                    strict: gpu,
                });
            }
            if !gpu {
                // Sparse operands run host-side: the copy engine is idle,
                // prefetch == resident by definition — the degenerate row.
                for (m, name) in [
                    (IterMethod::Cg, "sparse CG"),
                    (IterMethod::PipeCg, "sparse pipelined CG"),
                ] {
                    rows.push(Row {
                        kernel: name,
                        engine,
                        n: sparse_n,
                        ranks,
                        streaming: sparse_iter_makespan::<f64>(m, sparse_n, nnz, iters, 30, &p),
                        resident: sparse_iter_makespan_fused::<f64>(
                            m, sparse_n, nnz, iters, 30, &p,
                        ),
                        prefetch: sparse_iter_makespan_prefetch::<f64>(
                            m, sparse_n, nnz, iters, 30, &p,
                        ),
                        strict: false,
                    });
                }
            }
        }
    }

    // Table for the terminal.
    let header = ["kernel", "engine", "P", "streaming", "resident", "prefetch", "hidden"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kernel.to_string(),
                r.engine.to_string(),
                r.ranks.to_string(),
                fmt::secs(r.streaming),
                fmt::secs(r.resident),
                fmt::secs(r.prefetch),
                format!("{:.1}%", (1.0 - r.prefetch / r.resident) * 100.0),
            ]
        })
        .collect();
    println!("== Synchronous residency vs copy-engine prefetch ==");
    println!("{}", fmt::table(&header, &body));

    // Acceptance shape.
    for r in &rows {
        assert!(
            r.prefetch <= r.resident * (1.0 + 1e-9),
            "{} {} P={}: prefetch {} > resident {}",
            r.kernel,
            r.engine,
            r.ranks,
            r.prefetch,
            r.resident
        );
        assert!(
            r.resident <= r.streaming * (1.0 + 1e-9),
            "{} {} P={}: resident {} > streaming {}",
            r.kernel,
            r.engine,
            r.ranks,
            r.resident,
            r.streaming
        );
        if r.strict {
            assert!(
                r.prefetch < r.resident,
                "{} {} P={}: the copy engine must strictly win",
                r.kernel,
                r.engine,
                r.ranks
            );
        } else {
            assert!(
                (r.prefetch - r.resident).abs() <= 1e-12 * r.resident.max(1.0),
                "{} {} P={}: nothing streams — prefetch must be a wash",
                r.kernel,
                r.engine,
                r.ranks
            );
        }
    }

    // BENCH_prefetch.json (hand-rolled: the offline crate set has no serde).
    let mut json = format!(
        "{{\n  \"network\": \"gigabit_ethernet\",\n  \"device_mem_bytes\": {DEFAULT_DEVICE_MEM},\n  \"entries\": [\n"
    );
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"engine\": \"{}\", \"n\": {}, \"ranks\": {}, \
             \"streaming_secs\": {:.6e}, \"resident_secs\": {:.6e}, \
             \"prefetch_secs\": {:.6e}, \"hidden_frac\": {:.4}}}{}\n",
            r.kernel,
            r.engine,
            r.n,
            r.ranks,
            r.streaming,
            r.resident,
            r.prefetch,
            1.0 - r.prefetch / r.resident,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_prefetch.json", &json).expect("write BENCH_prefetch.json");
    println!(
        "wrote BENCH_prefetch.json ({} entries); the copy engine never loses.",
        rows.len()
    );
}
