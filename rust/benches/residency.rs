//! Bench: paper §3 copy-per-call streaming vs device residency + fused
//! BLAS-1 — the printed number behind the tile-cache subsystem
//! (`DESIGN.md` §12).
//!
//! For every paper rank count and both engine arms on the gigabit network,
//! evaluates the analytic model in its streaming and residency/fused forms
//! for the refactored hot paths:
//!
//! * **LU / Cholesky** — trailing updates over once-streamed broadcast
//!   panels and device-resident trailing tiles;
//! * **SUMMA** — fused `gemm_acc` with device-resident C;
//! * **CG / pipelined CG / BiCGSTAB** — resident matvec operands (budget
//!   permitting) + fused BLAS-1 chains; the sparse CG rows isolate the
//!   fusion win (sparse operands run host-side).
//!
//! Emits `BENCH_residency.json` and asserts the acceptance shape: cached
//! `<=` streaming on *every* configuration, strictly smaller wherever
//! `pcie_bw > 0` or a BLAS-1 chain was fused.
//!
//! ```sh
//! cargo bench --bench residency
//! ```

use cuplss::accel::{ComputeProfile, DEFAULT_DEVICE_MEM};
use cuplss::bench_harness::model::{
    chol_makespan, chol_makespan_resident, iter_makespan, iter_makespan_fused,
    lu_makespan_lookahead, lu_makespan_resident, sparse_iter_makespan,
    sparse_iter_makespan_fused, summa_makespan, summa_makespan_resident,
};
use cuplss::bench_harness::{ModelParams, PAPER_N, PAPER_RANKS};
use cuplss::comm::NetworkModel;
use cuplss::mesh::MeshShape;
use cuplss::solvers::IterMethod;
use cuplss::util::fmt;

struct Row {
    kernel: &'static str,
    engine: &'static str,
    n: usize,
    ranks: usize,
    streaming: f64,
    cached: f64,
    /// Must the cached arm win strictly (PCIe to save, or launches fused)?
    strict: bool,
}

fn params(ranks: usize, gpu: bool) -> ModelParams {
    ModelParams {
        tile: 256,
        shape: MeshShape::near_square(ranks),
        net: NetworkModel::gigabit_ethernet(),
        engine: if gpu {
            ComputeProfile::gtx280_cublas()
        } else {
            ComputeProfile::q6600_atlas()
        },
        panel_cpu: ComputeProfile::q6600_atlas(),
        swap_fraction: 0.5,
        device_mem: DEFAULT_DEVICE_MEM,
    }
}

fn main() {
    let grid = 1_000usize;
    let (sparse_n, nnz) = (grid * grid, 5 * grid * grid - 4 * grid);
    let iters = 100usize;
    let mut rows: Vec<Row> = Vec::new();

    for &ranks in PAPER_RANKS {
        for gpu in [false, true] {
            let p = params(ranks, gpu);
            let engine = if gpu { "MPI+CUDA" } else { "MPI+ATLAS" };
            rows.push(Row {
                kernel: "LU",
                engine,
                n: PAPER_N,
                ranks,
                streaming: lu_makespan_lookahead::<f32>(PAPER_N, &p),
                cached: lu_makespan_resident::<f32>(PAPER_N, &p),
                // Host arm: LU charges identically (nothing streams).
                strict: gpu,
            });
            rows.push(Row {
                kernel: "Cholesky",
                engine,
                n: PAPER_N,
                ranks,
                streaming: chol_makespan::<f32>(PAPER_N, &p),
                cached: chol_makespan_resident::<f32>(PAPER_N, &p),
                strict: gpu,
            });
            rows.push(Row {
                kernel: "SUMMA",
                engine,
                n: PAPER_N,
                ranks,
                // The cached arm also folds the host axpy into gemm_acc,
                // so it must win strictly on both arms.
                streaming: summa_makespan::<f32>(PAPER_N, &p, true),
                cached: summa_makespan_resident::<f32>(PAPER_N, &p, true),
                strict: true,
            });
            for (m, name) in [
                (IterMethod::Cg, "CG"),
                (IterMethod::PipeCg, "pipelined CG"),
                (IterMethod::Bicgstab, "BiCGSTAB"),
            ] {
                rows.push(Row {
                    kernel: name,
                    engine,
                    n: PAPER_N,
                    ranks,
                    streaming: iter_makespan::<f32>(m, PAPER_N, iters, 30, &p),
                    cached: iter_makespan_fused::<f32>(m, PAPER_N, iters, 30, &p),
                    // Fused BLAS-1 wins on both arms.
                    strict: true,
                });
            }
            if !gpu {
                // Sparse operands run host-side: pure fusion rows.
                for (m, name) in [
                    (IterMethod::Cg, "sparse CG"),
                    (IterMethod::PipeCg, "sparse pipelined CG"),
                ] {
                    rows.push(Row {
                        kernel: name,
                        engine,
                        n: sparse_n,
                        ranks,
                        streaming: sparse_iter_makespan::<f64>(m, sparse_n, nnz, iters, 30, &p),
                        cached: sparse_iter_makespan_fused::<f64>(
                            m, sparse_n, nnz, iters, 30, &p,
                        ),
                        strict: true,
                    });
                }
            }
        }
    }

    // Table for the terminal.
    let header = ["kernel", "engine", "P", "streaming", "cached", "saved"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kernel.to_string(),
                r.engine.to_string(),
                r.ranks.to_string(),
                fmt::secs(r.streaming),
                fmt::secs(r.cached),
                format!("{:.1}%", (1.0 - r.cached / r.streaming) * 100.0),
            ]
        })
        .collect();
    println!("== Streaming (paper §3 flow) vs device residency + fusion ==");
    println!("{}", fmt::table(&header, &body));

    // Acceptance shape.
    for r in &rows {
        assert!(
            r.cached <= r.streaming * (1.0 + 1e-9),
            "{} {} P={}: cached {} > streaming {}",
            r.kernel,
            r.engine,
            r.ranks,
            r.cached,
            r.streaming
        );
        if r.strict {
            assert!(
                r.cached < r.streaming,
                "{} {} P={}: residency/fusion must strictly win",
                r.kernel,
                r.engine,
                r.ranks
            );
        }
    }

    // BENCH_residency.json (hand-rolled: the offline crate set has no serde).
    let mut json = format!(
        "{{\n  \"network\": \"gigabit_ethernet\",\n  \"device_mem_bytes\": {DEFAULT_DEVICE_MEM},\n  \"entries\": [\n"
    );
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"engine\": \"{}\", \"n\": {}, \"ranks\": {}, \
             \"streaming_secs\": {:.6e}, \"cached_secs\": {:.6e}, \"saved_frac\": {:.4}}}{}\n",
            r.kernel,
            r.engine,
            r.n,
            r.ranks,
            r.streaming,
            r.cached,
            1.0 - r.cached / r.streaming,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_residency.json", &json).expect("write BENCH_residency.json");
    println!(
        "wrote BENCH_residency.json ({} entries); residency + fusion never lose.",
        rows.len()
    );
}
