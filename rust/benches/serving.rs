//! Bench: batched multi-RHS solves + the solve-request scheduler — the
//! printed numbers behind the serving subsystem (`DESIGN.md` §14).
//!
//! Two sections:
//!
//! * **amortization sweep** — for every paper rank count, both engine arms
//!   and RHS-panel widths k ∈ {1, 2, 4, 8}, evaluates each batched model
//!   twin against `k ×` its single-RHS baseline: **TRSM** (RHS-panel
//!   triangular substitution vs k looped `ptrsv` passes), **LU solve** and
//!   **Cholesky solve** (one factorization + two panel substitutions vs k
//!   full solves) and **blocked CG** (shared matvec sweeps, k-lane
//!   reductions, column-batched recurrences vs k looped solves);
//! * **serving scenario** — the deterministic mixed demo stream priced
//!   through [`cuplss::serve::schedule`] with the model twins as the batch
//!   pricer, batching on vs off (`--no-batching` A/B), reporting
//!   throughput and latency percentiles;
//! * **factor-cache scenario** — a longer stream whose direct operators
//!   repeat: the scheduler flags repeat `(workload, n, method)` batches,
//!   and a flagged batch prices only its two panel substitutions (the
//!   factors are resident from the earlier request) — the cross-request
//!   analogue of the within-batch amortization above, A/B'd against the
//!   same stream with the cache off (`--no-factor-cache`).
//!
//! Emits `BENCH_serving.json` and asserts the acceptance shape:
//! `batched <= k x single` on *every* configuration (strictly below for
//! k > 1 — launches, tile broadcasts and message latencies are paid per
//! panel step, not per vector), bit-exact equality at k = 1 (the batched
//! paths are the single-RHS paths), batched serving throughput strictly
//! above the unbatched A/B on a backlogged stream, and the factor cache
//! strictly raising throughput on the repeat stream (exactly two hits on
//! the 64-request demo stream; zero with the cache off).
//!
//! ```sh
//! cargo bench --bench serving
//! ```

use cuplss::accel::{ComputeProfile, DEFAULT_DEVICE_MEM};
use cuplss::bench_harness::model::{
    bicgstab_makespan_batched, cg_makespan_batched, chol_solve_makespan_batched, iter_makespan,
    lu_solve_makespan_batched, trsm_makespan, trsv_makespan,
};
use cuplss::bench_harness::{ModelParams, PAPER_N, PAPER_RANKS};
use cuplss::cluster::Method;
use cuplss::comm::NetworkModel;
use cuplss::mesh::MeshShape;
use cuplss::serve::{demo_stream, schedule, BatchCost, ServeConfig};
use cuplss::solvers::IterMethod;
use cuplss::util::fmt;

struct Row {
    kernel: &'static str,
    engine: &'static str,
    n: usize,
    ranks: usize,
    k: usize,
    single: f64,
    looped: f64,
    batched: f64,
}

struct ServeRow {
    engine: &'static str,
    ranks: usize,
    requests: usize,
    base_n: usize,
    batching: bool,
    batches: usize,
    throughput: f64,
    p50: f64,
    p95: f64,
    max: f64,
}

struct CacheRow {
    engine: &'static str,
    ranks: usize,
    requests: usize,
    base_n: usize,
    cache: bool,
    hits: usize,
    batches: usize,
    throughput: f64,
    p95: f64,
    max: f64,
}

fn params(ranks: usize, gpu: bool) -> ModelParams {
    ModelParams {
        tile: 256,
        shape: MeshShape::near_square(ranks),
        net: NetworkModel::gigabit_ethernet(),
        engine: if gpu {
            ComputeProfile::gtx280_cublas()
        } else {
            ComputeProfile::q6600_atlas()
        },
        panel_cpu: ComputeProfile::q6600_atlas(),
        swap_fraction: 0.5,
        device_mem: DEFAULT_DEVICE_MEM,
    }
}

/// Price one serving batch with the analytic twins: direct methods ride
/// one factorization + panel substitutions, CG and BiCGSTAB ride their
/// blocked sweeps, and anything without a batched twin prices as k looped
/// singles — honest: the scheduler never claims amortization the model
/// does not grant.
fn model_batch_cost(method: Method, n: usize, k: usize, iters: usize, p: &ModelParams) -> f64 {
    match method {
        Method::Lu => lu_solve_makespan_batched::<f32>(n, k, p),
        Method::Cholesky => chol_solve_makespan_batched::<f32>(n, k, p),
        Method::Iterative(IterMethod::Cg) => cg_makespan_batched::<f32>(n, k, iters, p),
        Method::Iterative(IterMethod::Bicgstab) => {
            bicgstab_makespan_batched::<f32>(n, k, iters, p)
        }
        Method::Iterative(m) => k as f64 * iter_makespan::<f32>(m, n, iters, 30, p),
    }
}

fn main() {
    let iters = 100usize;
    let mut rows: Vec<Row> = Vec::new();

    for &ranks in PAPER_RANKS {
        for gpu in [false, true] {
            let p = params(ranks, gpu);
            let engine = if gpu { "MPI+CUDA" } else { "MPI+ATLAS" };
            // k = 1 is the single-RHS path, bit for bit.
            assert_eq!(
                trsm_makespan::<f32>(PAPER_N, 1, &p),
                trsv_makespan::<f32>(PAPER_N, &p),
                "{engine} P={ranks}: a one-column panel must price as ptrsv"
            );
            assert_eq!(
                cg_makespan_batched::<f32>(PAPER_N, 1, iters, &p),
                iter_makespan::<f32>(IterMethod::Cg, PAPER_N, iters, 30, &p),
                "{engine} P={ranks}: one-column blocked CG must price as CG"
            );
            assert_eq!(
                bicgstab_makespan_batched::<f32>(PAPER_N, 1, iters, &p),
                iter_makespan::<f32>(IterMethod::Bicgstab, PAPER_N, iters, 30, &p),
                "{engine} P={ranks}: one-column blocked BiCGSTAB must price as BiCGSTAB"
            );
            let singles = [
                ("TRSM", trsm_makespan::<f32>(PAPER_N, 1, &p)),
                ("LU solve", lu_solve_makespan_batched::<f32>(PAPER_N, 1, &p)),
                ("Cholesky solve", chol_solve_makespan_batched::<f32>(PAPER_N, 1, &p)),
                ("blocked CG", cg_makespan_batched::<f32>(PAPER_N, 1, iters, &p)),
            ];
            for k in [1usize, 2, 4, 8] {
                for (kernel, single) in singles {
                    let batched = match kernel {
                        "TRSM" => trsm_makespan::<f32>(PAPER_N, k, &p),
                        "LU solve" => lu_solve_makespan_batched::<f32>(PAPER_N, k, &p),
                        "Cholesky solve" => chol_solve_makespan_batched::<f32>(PAPER_N, k, &p),
                        _ => cg_makespan_batched::<f32>(PAPER_N, k, iters, &p),
                    };
                    rows.push(Row {
                        kernel,
                        engine,
                        n: PAPER_N,
                        ranks,
                        k,
                        single,
                        looped: k as f64 * single,
                        batched,
                    });
                }
            }
        }
    }

    // Serving scenario: the mixed demo stream, batching on vs off.
    let (n_requests, base_n, serve_ranks) = (16usize, 20_000usize, 16usize);
    let stream = demo_stream(n_requests, base_n);
    let mut serve_rows: Vec<ServeRow> = Vec::new();
    for gpu in [false, true] {
        let p = params(serve_ranks, gpu);
        let engine = if gpu { "MPI+CUDA" } else { "MPI+ATLAS" };
        for batching in [true, false] {
            let cfg =
                ServeConfig { rhs_batch: 8, batching, factor_cache: false, ..ServeConfig::default() };
            let rep = schedule(&stream, &cfg, |members, _ctx| {
                let head = members[0];
                let k = members.len();
                let makespan = model_batch_cost(head.method, head.n, k, iters, &p);
                Ok(BatchCost {
                    makespan,
                    per_request_secs: vec![makespan / k as f64; k],
                    max_err: 0.0,
                    degraded: false,
                })
            })
            .expect("demo stream is arrival-ordered");
            serve_rows.push(ServeRow {
                engine,
                ranks: serve_ranks,
                requests: n_requests,
                base_n,
                batching,
                batches: rep.batches,
                throughput: rep.throughput(),
                p50: rep.p50(),
                p95: rep.p95(),
                max: rep.latency_max(),
            });
        }
    }

    // Factor-cache scenario: a longer stream whose direct operators repeat
    // (the 64-request demo stream re-enters the LU (diagdom, 32) and
    // Cholesky (spd, 96) operators in later groups).  A flagged batch
    // prices only its two panel substitutions — the factorization (and for
    // Cholesky the transpose redistribution) is resident from the earlier
    // request.
    let (c_requests, c_base_n) = (64usize, 32usize);
    let cache_stream = demo_stream(c_requests, c_base_n);
    let mut cache_rows: Vec<CacheRow> = Vec::new();
    for gpu in [false, true] {
        let p = params(serve_ranks, gpu);
        let engine = if gpu { "MPI+CUDA" } else { "MPI+ATLAS" };
        for cache in [true, false] {
            let cfg = ServeConfig {
                rhs_batch: 8,
                batching: true,
                factor_cache: cache,
                ..ServeConfig::default()
            };
            let rep = schedule(&cache_stream, &cfg, |members, ctx| {
                let head = members[0];
                let k = members.len();
                let makespan = if ctx.factor_cached {
                    // Both substitutions of the resident factors; nothing
                    // else is charged — matching Cluster::solve_batch_cached.
                    2.0 * trsm_makespan::<f32>(head.n, k, &p)
                } else {
                    model_batch_cost(head.method, head.n, k, iters, &p)
                };
                Ok(BatchCost {
                    makespan,
                    per_request_secs: vec![makespan / k as f64; k],
                    max_err: 0.0,
                    degraded: false,
                })
            })
            .expect("demo stream is arrival-ordered");
            cache_rows.push(CacheRow {
                engine,
                ranks: serve_ranks,
                requests: c_requests,
                base_n: c_base_n,
                cache,
                hits: rep.factor_cache_hits,
                batches: rep.batches,
                throughput: rep.throughput(),
                p95: rep.p95(),
                max: rep.latency_max(),
            });
        }
    }

    // Tables for the terminal.
    let header = ["kernel", "engine", "P", "k", "k x single", "batched", "speedup"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kernel.to_string(),
                r.engine.to_string(),
                r.ranks.to_string(),
                r.k.to_string(),
                fmt::secs(r.looped),
                fmt::secs(r.batched),
                format!("{:.2}x", r.looped / r.batched),
            ]
        })
        .collect();
    println!("== Batched multi-RHS solves vs k looped singles ==");
    println!("{}", fmt::table(&header, &body));

    let sheader =
        ["engine", "P", "batching", "batches", "req/s", "p50", "p95", "max latency"];
    let sbody: Vec<Vec<String>> = serve_rows
        .iter()
        .map(|r| {
            vec![
                r.engine.to_string(),
                r.ranks.to_string(),
                if r.batching { "on".to_string() } else { "off".to_string() },
                r.batches.to_string(),
                format!("{:.3}", r.throughput),
                fmt::secs(r.p50),
                fmt::secs(r.p95),
                fmt::secs(r.max),
            ]
        })
        .collect();
    println!("== Serving the mixed demo stream ({n_requests} requests) ==");
    println!("{}", fmt::table(&sheader, &sbody));

    let cheader = ["engine", "P", "cache", "hits", "batches", "req/s", "p95", "max latency"];
    let cbody: Vec<Vec<String>> = cache_rows
        .iter()
        .map(|r| {
            vec![
                r.engine.to_string(),
                r.ranks.to_string(),
                if r.cache { "on".to_string() } else { "off".to_string() },
                r.hits.to_string(),
                r.batches.to_string(),
                format!("{:.3}", r.throughput),
                fmt::secs(r.p95),
                fmt::secs(r.max),
            ]
        })
        .collect();
    println!("== Cross-request factor cache ({c_requests} requests, repeats) ==");
    println!("{}", fmt::table(&cheader, &cbody));

    // Acceptance shape.
    for r in &rows {
        if r.k == 1 {
            assert!(
                r.batched == r.single,
                "{} {} P={}: k=1 must be the single-RHS path bit for bit",
                r.kernel,
                r.engine,
                r.ranks
            );
        } else {
            assert!(
                r.batched < r.looped,
                "{} {} P={} k={}: batched {} must beat {} looped singles",
                r.kernel,
                r.engine,
                r.ranks,
                r.k,
                r.batched,
                r.looped
            );
        }
    }
    for pair in serve_rows.chunks(2) {
        let (on, off) = (&pair[0], &pair[1]);
        assert!(on.batching && !off.batching);
        assert!(
            on.throughput > off.throughput,
            "{}: batching must raise throughput ({} vs {})",
            on.engine,
            on.throughput,
            off.throughput
        );
        assert!(
            on.max <= off.max * (1.0 + 1e-9),
            "{}: batching must not worsen the tail on a backlogged stream",
            on.engine
        );
    }
    for pair in cache_rows.chunks(2) {
        let (on, off) = (&pair[0], &pair[1]);
        assert!(on.cache && !off.cache);
        assert_eq!(on.hits, 2, "{}: the 64-request demo stream repeats exactly twice", on.engine);
        assert_eq!(off.hits, 0, "{}: the cache-off arm must never flag a hit", off.engine);
        assert_eq!(on.batches, off.batches, "the cache changes pricing, not grouping");
        assert!(
            on.throughput > off.throughput,
            "{}: the factor cache must raise throughput ({} vs {})",
            on.engine,
            on.throughput,
            off.throughput
        );
        assert!(
            on.max <= off.max * (1.0 + 1e-9),
            "{}: the factor cache must not worsen the tail",
            on.engine
        );
    }

    // BENCH_serving.json (hand-rolled: the offline crate set has no serde).
    let mut json = format!(
        "{{\n  \"network\": \"gigabit_ethernet\",\n  \"tile\": 256,\n  \"iters\": {iters},\n  \"entries\": [\n"
    );
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"engine\": \"{}\", \"n\": {}, \"ranks\": {}, \
             \"k\": {}, \"single_secs\": {:.6e}, \"looped_secs\": {:.6e}, \
             \"batched_secs\": {:.6e}, \"speedup\": {:.4}}}{}\n",
            r.kernel,
            r.engine,
            r.n,
            r.ranks,
            r.k,
            r.single,
            r.looped,
            r.batched,
            r.looped / r.batched,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"serving\": [\n");
    for (i, r) in serve_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"engine\": \"{}\", \"ranks\": {}, \"requests\": {}, \"base_n\": {}, \
             \"batching\": {}, \"batches\": {}, \"throughput_rps\": {:.6e}, \
             \"p50_secs\": {:.6e}, \"p95_secs\": {:.6e}, \"max_secs\": {:.6e}}}{}\n",
            r.engine,
            r.ranks,
            r.requests,
            r.base_n,
            r.batching,
            r.batches,
            r.throughput,
            r.p50,
            r.p95,
            r.max,
            if i + 1 < serve_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"factor_cache\": [\n");
    for (i, r) in cache_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"engine\": \"{}\", \"ranks\": {}, \"requests\": {}, \"base_n\": {}, \
             \"cache\": {}, \"hits\": {}, \"batches\": {}, \"throughput_rps\": {:.6e}, \
             \"p95_secs\": {:.6e}, \"max_secs\": {:.6e}}}{}\n",
            r.engine,
            r.ranks,
            r.requests,
            r.base_n,
            r.cache,
            r.hits,
            r.batches,
            r.throughput,
            r.p95,
            r.max,
            if i + 1 < cache_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_serving.json", &json).expect("write BENCH_serving.json");
    println!(
        "wrote BENCH_serving.json ({} entries, {} serving + {} cache rows); \
         batching and the factor cache never lose.",
        rows.len(),
        serve_rows.len(),
        cache_rows.len()
    );
}
