//! Bench: regenerate **Figure 4** — speedup of the parallel LU solver at
//! n = 60000 over 1/2/4/8/16 ranks, MPI+CUDA vs MPI+ATLAS, single precision,
//! plus the double-precision variant (E3) and the Cholesky companion (E5).
//!
//! ```sh
//! cargo bench --bench fig4_direct
//! cargo bench --bench fig4_direct -- --dp          # DP only
//! cargo bench --bench fig4_direct -- --cholesky    # include Cholesky rows
//! ```

use cuplss::bench_harness::{fig3_series, fig4_series, figures::render_table, PAPER_N};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let dp_only = args.iter().any(|a| a == "--dp");
    let cholesky = args.iter().any(|a| a == "--cholesky");
    let n = PAPER_N;
    let tile = 256;

    if !dp_only {
        let sp = fig4_series::<f32>(n, tile, cholesky);
        println!(
            "{}",
            render_table(
                &format!("Figure 4 — direct-solver speedup (n={n}, single precision)"),
                &sp
            )
        );
        check_shape::<f32>(&sp, n, tile, "SP");
    }
    let dp = fig4_series::<f64>(n, tile, cholesky);
    println!(
        "{}",
        render_table(
            &format!("Figure 4 (E3) — direct-solver speedup (n={n}, double precision)"),
            &dp
        )
    );
    check_shape::<f64>(&dp, n, tile, "DP");

    println!("paper-shape checks passed: monotone, CUDA > ATLAS, LU > iterative (CUDA arm).");
}

fn check_shape<S: cuplss::Scalar>(
    series: &[cuplss::bench_harness::FigureSeries],
    n: usize,
    tile: usize,
    label: &str,
) {
    for s in series {
        for w in s.points.windows(2) {
            assert!(
                w[1].speedup > w[0].speedup,
                "[{label}] {}: speedup must grow with P",
                s.label
            );
        }
    }
    let lu_cuda = series.iter().find(|s| s.label == "LU (MPI+CUDA)").unwrap();
    let lu_atlas = series.iter().find(|s| s.label == "LU (MPI+ATLAS)").unwrap();
    for (c, a) in lu_cuda.points.iter().zip(&lu_atlas.points) {
        assert!(c.speedup > a.speedup, "[{label}] CUDA must beat ATLAS at P={}", c.ranks);
    }
    // §5: factorisation speedup exceeds the iterative methods' (CUDA arm).
    let best_iter = fig3_series::<S>(n, 100, tile)
        .iter()
        .filter(|s| s.label.contains("CUDA"))
        .map(|s| s.final_speedup())
        .fold(0.0, f64::max);
    assert!(
        lu_cuda.final_speedup() > best_iter,
        "[{label}] LU {} must out-scale iterative {best_iter}",
        lu_cuda.final_speedup()
    );
}
