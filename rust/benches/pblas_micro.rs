//! Micro-benchmarks (wall-clock, in-tree harness): the real execution speed
//! of the local engines and the distributed primitives on *this* machine.
//! These feed the §Perf optimisation log in EXPERIMENTS.md — everything else
//! in `benches/` reports modelled 2008-cluster time, this file reports what
//! the library actually costs to run today.
//!
//! ```sh
//! cargo bench --bench pblas_micro
//! ```

use std::sync::Arc;

use cuplss::accel::{CpuEngine, Engine, XlaEngine};
use cuplss::comm::{NetworkModel, World};
use cuplss::dist::{Descriptor, DistMatrix, DistVector};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::{pdot, pgemv, Ctx};
use cuplss::runtime::Runtime;
use cuplss::util::timer::bench;
use cuplss::util::{fmt, Prng};

const T: usize = 256;

fn flops_row(label: &str, stats: &cuplss::util::TimerStats, flops: u64) -> Vec<String> {
    vec![
        label.to_string(),
        fmt::secs(stats.mean()),
        fmt::secs(stats.min()),
        fmt::flops(flops as f64 / stats.min()),
    ]
}

fn main() {
    let mut rows = Vec::new();
    let mut rng = Prng::new(99);

    // --- local engines: the tile GEMM hot path --------------------------
    let mut a = vec![0.0f32; T * T];
    let mut b = vec![0.0f32; T * T];
    let mut c = vec![0.0f32; T * T];
    rng.fill_normal(&mut a);
    rng.fill_normal(&mut b);
    let cpu = CpuEngine::new(T);
    let stats = bench(2, 10, || {
        Engine::<f32>::gemm_update(&cpu, &mut c, &a, &b).unwrap();
    });
    let gflops = cuplss::accel::op_flops("gemm_update", T as u64);
    rows.push(flops_row("CpuEngine gemm_update f32 256", &stats, gflops));

    let mut ad = vec![0.0f64; T * T];
    let mut bd = vec![0.0f64; T * T];
    let mut cd = vec![0.0f64; T * T];
    rng.fill_normal(&mut ad);
    rng.fill_normal(&mut bd);
    let stats = bench(2, 10, || {
        Engine::<f64>::gemm_update(&cpu, &mut cd, &ad, &bd).unwrap();
    });
    rows.push(flops_row("CpuEngine gemm_update f64 256", &stats, gflops));

    // --- PJRT engine (needs artifacts) -----------------------------------
    let artifact_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&artifact_dir).join("manifest.txt").exists() {
        let rt = Runtime::new(&artifact_dir).expect("runtime");
        let xla = XlaEngine::<f32>::new(&rt, T).expect("engine");
        xla.warmup().unwrap();
        let stats = bench(2, 10, || {
            xla.gemm_update(&mut c, &a, &b).unwrap();
        });
        rows.push(flops_row("XlaEngine gemm_update f32 256 (PJRT)", &stats, gflops));
        let mut y = vec![0.0f32; T];
        let x = vec![1.0f32; T];
        let stats = bench(2, 20, || {
            xla.gemv(&a, &x, &mut y).unwrap();
        });
        rows.push(flops_row(
            "XlaEngine gemv f32 256 (PJRT)",
            &stats,
            cuplss::accel::op_flops("gemv", T as u64),
        ));
    } else {
        eprintln!("(artifacts missing: skipping PJRT rows)");
    }

    // --- distributed primitives (wall time, 4 ranks) ----------------------
    let n = 2048usize;
    for (label, ranks) in [("pgemv n=2048 P=1", 1usize), ("pgemv n=2048 P=4", 4)] {
        let stats = bench(1, 5, || {
            World::run::<f32, _, _>(ranks, NetworkModel::ideal(), |comm| {
                let mesh = Mesh::new(&comm, MeshShape::near_square(comm.size()));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(256)));
                let desc = Descriptor::new(n, n, 256, mesh.shape());
                let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
                    ((i + j) % 17) as f32
                });
                let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| i as f32);
                let y = pgemv(&ctx, &a, &x);
                pdot(&ctx, &y, &y)
            });
        });
        rows.push(flops_row(label, &stats, 2 * (n * n) as u64));
    }

    println!(
        "{}",
        fmt::table(&["op", "mean", "best", "rate (best)"], &rows)
    );
    println!("(wall-clock on this machine; modelled cluster time lives in fig3/fig4 benches)");
}
