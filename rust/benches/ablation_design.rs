//! Ablation E9 — our own design choices, quantified with the same model
//! used for the paper figures:
//!
//! 1. **tile size** — 128 / 256 / 512 on LU and BiCGSTAB at P = 16
//!    (smaller tiles = more parallel slack + more per-call overhead);
//! 2. **mesh shape** — 16 ranks as 1x16 / 2x8 / 4x4 (near-square wins for
//!    LU, the classic block-cyclic result);
//! 3. **gather vs broadcast panel exchange volume** (message-count model of
//!    the LU panel phase).
//!
//! ```sh
//! cargo bench --bench ablation_design
//! ```

use cuplss::accel::ComputeProfile;
use cuplss::bench_harness::model::{iter_makespan, lu_makespan, ModelParams};
use cuplss::comm::NetworkModel;
use cuplss::mesh::MeshShape;
use cuplss::solvers::IterMethod;
use cuplss::util::fmt;

fn main() {
    let n = 30_000; // large enough to be compute-dominated, fast to model
    let net = NetworkModel::gigabit_ethernet();
    let gpu = ComputeProfile::gtx280_cublas();
    let cpu = ComputeProfile::q6600_atlas();

    println!("== E9.1: tile-size sweep (P=16, n={n}, SP, CUDA arm) ==");
    let mut rows = Vec::new();
    for tile in [128usize, 256, 512] {
        let p = ModelParams {
            tile,
            shape: MeshShape::near_square(16),
            net,
            engine: gpu,
            panel_cpu: cpu,
            swap_fraction: 0.5,
            device_mem: cuplss::accel::DEFAULT_DEVICE_MEM,
        };
        let lu = lu_makespan::<f32>(n, &p);
        let it = iter_makespan::<f32>(IterMethod::Bicgstab, n, 100, 30, &p);
        rows.push(vec![tile.to_string(), fmt::secs(lu), fmt::secs(it)]);
    }
    println!("{}", fmt::table(&["tile", "LU makespan", "BiCGSTAB makespan"], &rows));

    println!("== E9.2: mesh-shape sweep (16 ranks, n={n}, SP, ATLAS arm) ==");
    let mut rows = Vec::new();
    let mut best = (String::new(), f64::INFINITY);
    for (pr, pc) in [(1usize, 16usize), (2, 8), (4, 4), (8, 2), (16, 1)] {
        let p = ModelParams {
            tile: 256,
            shape: MeshShape::new(pr, pc),
            net,
            engine: cpu,
            panel_cpu: cpu,
            swap_fraction: 0.5,
            device_mem: cuplss::accel::DEFAULT_DEVICE_MEM,
        };
        let lu = lu_makespan::<f32>(n, &p);
        if lu < best.1 {
            best = (format!("{pr}x{pc}"), lu);
        }
        rows.push(vec![format!("{pr}x{pc}"), fmt::secs(lu)]);
    }
    println!("{}", fmt::table(&["mesh", "LU makespan"], &rows));
    println!("best mesh: {} — near-square minimises the broadcast volume", best.0);
    assert_eq!(best.0, "4x4", "near-square must win for LU");

    println!("== E9.3: LU panel-exchange volume per step (n={n}, tile=256) ==");
    let kt = n / 256;
    let mut rows = Vec::new();
    for (pr, _pc) in [(4usize, 4usize)] {
        // gather+scatter (our design) vs hypothetical all-broadcast panel
        let gather_msgs = 2 * (kt - kt / pr);
        let bcast_msgs = kt * (usize::BITS - (pr - 1).leading_zeros()) as usize;
        rows.push(vec![
            "gather->getrf->scatter (ours)".into(),
            gather_msgs.to_string(),
        ]);
        rows.push(vec!["panel row-bcast (alternative)".into(), bcast_msgs.to_string()]);
    }
    println!("{}", fmt::table(&["panel scheme", "tile messages at k=0"], &rows));
    println!("E9 checks passed.");
}
