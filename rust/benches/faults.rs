//! Bench: checkpointed fault recovery vs recompute-from-scratch — the
//! printed numbers behind the fault model (`DESIGN.md` §18).
//!
//! For every paper rank count, both engine arms, four kernels (LU,
//! Cholesky, CG, BiCGSTAB) and three crash points, evaluates the analytic
//! model in two arms that differ **only** in the recovery strategy:
//!
//! * **full** — no checkpoints: a crash at panel (iteration) `c` costs the
//!   fault-free run + the reboot charge + a full replay of `[0, c)`;
//! * **ckpt** — panel-granularity checkpoints every `every` panels
//!   (iterations): the fault-free run is taxed one D2H leg per checkpoint,
//!   and the crash replays only `[last_checkpoint, c)` plus one restore
//!   leg.
//!
//! Emits `BENCH_faults.json` and asserts the acceptance shape:
//! the fault-free checkpointed makespan is the base **plus exactly the
//! priced D2H legs** (bitwise — nothing else changes by construction), and
//! checkpointed recovery strictly undercuts full recompute on every grid
//! point (every crash lands at or past the first checkpoint, so at least
//! `every` panels of BLAS-3 / matvec replay are saved against a handful of
//! O(local-share) PCIe legs).
//!
//! ```sh
//! cargo bench --bench faults
//! ```

use cuplss::accel::{ComputeProfile, DEFAULT_DEVICE_MEM};
use cuplss::bench_harness::model::{
    chol_makespan_ckpt, chol_makespan_gpudirect, chol_recovery_ckpt, chol_recovery_full, ckpt_leg,
    iter_makespan_ckpt, iter_makespan_gpudirect, iter_recovery_ckpt, iter_recovery_full,
    krylov_snap_leg, krylov_snap_period, lu_makespan_ckpt, lu_makespan_gpudirect,
    lu_recovery_ckpt, lu_recovery_full, n_checkpoints, n_panels,
};
use cuplss::bench_harness::{ModelParams, PAPER_N, PAPER_RANKS};
use cuplss::comm::{FaultPlan, NetworkModel};
use cuplss::mesh::MeshShape;
use cuplss::solvers::IterMethod;
use cuplss::util::fmt;

const ITERS: usize = 100;
const RESTART: usize = 30;
const EVERY_DIRECT: usize = 16;
const EVERY_KRYLOV: usize = 10;
const CRASH_FRACS: [f64; 3] = [0.25, 0.5, 0.9];

struct Row {
    kernel: &'static str,
    engine: &'static str,
    n: usize,
    ranks: usize,
    pr: usize,
    pc: usize,
    every: usize,
    crash: usize,
    base_secs: f64,
    ckpt_secs: f64,
    legs_secs: f64,
    full_recovery_secs: f64,
    ckpt_recovery_secs: f64,
    /// Did the crash land at or past the first checkpoint (the strict-win
    /// regime)?  True on every grid point by construction.
    strict: bool,
}

fn params(ranks: usize, gpu: bool) -> ModelParams {
    ModelParams {
        tile: 256,
        shape: MeshShape::near_square(ranks),
        net: NetworkModel::gigabit_ethernet(),
        engine: if gpu {
            ComputeProfile::gtx280_cublas()
        } else {
            ComputeProfile::q6600_atlas()
        },
        panel_cpu: ComputeProfile::q6600_atlas(),
        swap_fraction: 0.5,
        device_mem: DEFAULT_DEVICE_MEM,
    }
}

fn main() {
    let reboot = FaultPlan::default().reboot_secs;
    let mut rows: Vec<Row> = Vec::new();

    for &ranks in PAPER_RANKS {
        for gpu in [false, true] {
            let p = params(ranks, gpu);
            let (pr, pc) = (p.shape.pr, p.shape.pc);
            let engine = if gpu { "MPI+CUDA" } else { "MPI+ATLAS" };

            // Direct kernels: crash points over the panel count.
            let panels = n_panels(PAPER_N, &p);
            let dlegs = n_checkpoints(panels, EVERY_DIRECT) as f64 * ckpt_leg::<f32>(PAPER_N, &p);
            for &frac in &CRASH_FRACS {
                let crash = ((panels as f64 * frac) as usize).max(EVERY_DIRECT);
                rows.push(Row {
                    kernel: "LU",
                    engine,
                    n: PAPER_N,
                    ranks,
                    pr,
                    pc,
                    every: EVERY_DIRECT,
                    crash,
                    base_secs: lu_makespan_gpudirect::<f32>(PAPER_N, &p),
                    ckpt_secs: lu_makespan_ckpt::<f32>(PAPER_N, EVERY_DIRECT, &p),
                    legs_secs: dlegs,
                    full_recovery_secs: lu_recovery_full::<f32>(PAPER_N, crash, reboot, &p),
                    ckpt_recovery_secs: lu_recovery_ckpt::<f32>(
                        PAPER_N,
                        EVERY_DIRECT,
                        crash,
                        reboot,
                        &p,
                    ),
                    strict: crash >= EVERY_DIRECT,
                });
                rows.push(Row {
                    kernel: "Cholesky",
                    engine,
                    n: PAPER_N,
                    ranks,
                    pr,
                    pc,
                    every: EVERY_DIRECT,
                    crash,
                    base_secs: chol_makespan_gpudirect::<f32>(PAPER_N, &p),
                    ckpt_secs: chol_makespan_ckpt::<f32>(PAPER_N, EVERY_DIRECT, &p),
                    legs_secs: dlegs,
                    full_recovery_secs: chol_recovery_full::<f32>(PAPER_N, crash, reboot, &p),
                    ckpt_recovery_secs: chol_recovery_ckpt::<f32>(
                        PAPER_N,
                        EVERY_DIRECT,
                        crash,
                        reboot,
                        &p,
                    ),
                    strict: crash >= EVERY_DIRECT,
                });
            }

            // Krylov kernels: crash points over the iteration count.
            for (m, name) in [(IterMethod::Cg, "CG"), (IterMethod::Bicgstab, "BiCGSTAB")] {
                let period = krylov_snap_period(m, EVERY_KRYLOV, RESTART);
                let klegs =
                    n_checkpoints(ITERS, period) as f64 * krylov_snap_leg::<f32>(m, PAPER_N, &p);
                for &frac in &CRASH_FRACS {
                    let crash = ((ITERS as f64 * frac) as usize).max(period);
                    rows.push(Row {
                        kernel: name,
                        engine,
                        n: PAPER_N,
                        ranks,
                        pr,
                        pc,
                        every: period,
                        crash,
                        base_secs: iter_makespan_gpudirect::<f32>(m, PAPER_N, ITERS, RESTART, &p),
                        ckpt_secs: iter_makespan_ckpt::<f32>(
                            m,
                            PAPER_N,
                            ITERS,
                            RESTART,
                            EVERY_KRYLOV,
                            &p,
                        ),
                        legs_secs: klegs,
                        full_recovery_secs: iter_recovery_full::<f32>(
                            m, PAPER_N, ITERS, RESTART, crash, reboot, &p,
                        ),
                        ckpt_recovery_secs: iter_recovery_ckpt::<f32>(
                            m,
                            PAPER_N,
                            ITERS,
                            RESTART,
                            EVERY_KRYLOV,
                            crash,
                            reboot,
                            &p,
                        ),
                        strict: crash >= period,
                    });
                }
            }
        }
    }

    // Table for the terminal (one crash point per kernel keeps it readable).
    let header = ["kernel", "engine", "P", "crash", "full rec", "ckpt rec", "saved"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .filter(|r| r.crash as f64 >= 0.45 * if r.kernel == "LU" || r.kernel == "Cholesky" {
            n_panels(r.n, &params(r.ranks, r.engine == "MPI+CUDA")) as f64
        } else {
            ITERS as f64
        } && (r.crash as f64) < 0.6 * if r.kernel == "LU" || r.kernel == "Cholesky" {
            n_panels(r.n, &params(r.ranks, r.engine == "MPI+CUDA")) as f64
        } else {
            ITERS as f64
        })
        .map(|r| {
            vec![
                r.kernel.to_string(),
                r.engine.to_string(),
                r.ranks.to_string(),
                r.crash.to_string(),
                fmt::secs(r.full_recovery_secs),
                fmt::secs(r.ckpt_recovery_secs),
                format!("{:.1}%", (1.0 - r.ckpt_recovery_secs / r.full_recovery_secs) * 100.0),
            ]
        })
        .collect();
    println!("== Checkpointed recovery vs full recompute (n = {PAPER_N}, mid-run crash) ==");
    println!("{}", fmt::table(&header, &body));

    // Acceptance shape.
    for r in &rows {
        let label = format!("{} {} P={} crash={}", r.kernel, r.engine, r.ranks, r.crash);
        assert_eq!(
            r.ckpt_secs,
            r.base_secs + r.legs_secs,
            "{label}: fault-free ckpt overhead must be exactly the priced D2H legs"
        );
        assert!(
            r.strict,
            "{label}: every grid crash must land at or past the first checkpoint"
        );
        assert!(
            r.ckpt_recovery_secs < r.full_recovery_secs,
            "{label}: ckpt recovery {} must strictly undercut recompute {}",
            r.ckpt_recovery_secs,
            r.full_recovery_secs
        );
    }

    // BENCH_faults.json (hand-rolled: the offline crate set has no serde).
    let mut json = format!(
        "{{\n  \"network\": \"gigabit_ethernet\",\n  \"tile\": 256,\n  \"n\": {PAPER_N},\n  \
         \"iters\": {ITERS},\n  \"every_direct\": {EVERY_DIRECT},\n  \
         \"every_krylov\": {EVERY_KRYLOV},\n  \"reboot_secs\": {reboot:.6e},\n  \"entries\": [\n"
    );
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"engine\": \"{}\", \"n\": {}, \"ranks\": {}, \
             \"pr\": {}, \"pc\": {}, \"every\": {}, \"crash\": {}, \"base_secs\": {:.6e}, \
             \"ckpt_secs\": {:.6e}, \"legs_secs\": {:.6e}, \"full_recovery_secs\": {:.6e}, \
             \"ckpt_recovery_secs\": {:.6e}, \"saved_frac\": {:.4}, \"strict\": {}}}{}\n",
            r.kernel,
            r.engine,
            r.n,
            r.ranks,
            r.pr,
            r.pc,
            r.every,
            r.crash,
            r.base_secs,
            r.ckpt_secs,
            r.legs_secs,
            r.full_recovery_secs,
            r.ckpt_recovery_secs,
            1.0 - r.ckpt_recovery_secs / r.full_recovery_secs,
            r.strict,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_faults.json", &json).expect("write BENCH_faults.json");
    println!(
        "wrote BENCH_faults.json ({} rows); checkpointed recovery never loses.",
        rows.len()
    );
}
