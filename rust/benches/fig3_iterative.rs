//! Bench: regenerate **Figure 3** — speedup of the parallel iterative
//! solvers (GMRES, BiCG, BiCGSTAB) at n = 60000 over 1/2/4/8/16 ranks,
//! MPI+CUDA vs MPI+ATLAS local compute, single precision (the paper's
//! figure) plus the double-precision variant the text reports (E3).
//!
//! ```sh
//! cargo bench --bench fig3_iterative            # both precisions
//! cargo bench --bench fig3_iterative -- --dp    # double precision only
//! ```
//!
//! Model mode (DESIGN.md §8): same cost structure as the live virtual clock,
//! validated by `cargo bench --bench calibration`.

use cuplss::bench_harness::{fig3_series, figures::render_table, PAPER_N};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let dp_only = args.iter().any(|a| a == "--dp");
    let n = PAPER_N;
    let iters = 100;
    let tile = 256;

    if !dp_only {
        let sp = fig3_series::<f32>(n, iters, tile);
        println!(
            "{}",
            render_table(
                &format!("Figure 3 — iterative-solver speedup (n={n}, single precision)"),
                &sp
            )
        );
        check_shape(&sp, "SP");
    }
    let dp = fig3_series::<f64>(n, iters, tile);
    println!(
        "{}",
        render_table(
            &format!("Figure 3 (E3) — iterative-solver speedup (n={n}, double precision)"),
            &dp
        )
    );
    check_shape(&dp, "DP");

    println!("paper-shape checks passed: monotone scaling, CUDA >= ATLAS per method.");
}

/// Assert the qualitative properties the paper's Figure 3 exhibits.
fn check_shape(series: &[cuplss::bench_harness::FigureSeries], label: &str) {
    for s in series {
        for w in s.points.windows(2) {
            assert!(
                w[1].speedup > w[0].speedup,
                "[{label}] {}: speedup must grow with P: {:?}",
                s.label,
                s.points
            );
        }
    }
    // CUDA arm >= ATLAS arm for the same method.
    for m in ["GMRES", "BiCG (", "BiCGSTAB"] {
        let cuda = series
            .iter()
            .find(|s| s.label.starts_with(m) && s.label.contains("CUDA"))
            .expect("cuda series");
        let atlas = series
            .iter()
            .find(|s| s.label.starts_with(m) && s.label.contains("ATLAS"))
            .expect("atlas series");
        for (c, a) in cuda.points.iter().zip(&atlas.points) {
            assert!(
                c.speedup >= a.speedup * 0.95,
                "[{label}] {m} P={}: CUDA {} vs ATLAS {}",
                c.ranks,
                c.speedup,
                a.speedup
            );
        }
    }
}
