//! Bench: mixed precision end to end vs uniform f64 — the printed numbers
//! behind the mixed-precision subsystem (`DESIGN.md` §17).
//!
//! For every paper rank count and both engine arms, evaluates the analytic
//! model in two arms that differ **only** in the arithmetic/storage width
//! of the heavy phase:
//!
//! * **f64** — the uniform wide flow: the `*_gpudirect` twins at `f64`
//!   (the best all-subsystems-on baseline this repo models);
//! * **mixed** — the `*_refined` / `*_mixed` twins: f32 factorization +
//!   [`MODEL_REFINE_ITERS`] wide refinement sweeps for the direct solvers,
//!   f32-storage / f64-accumulate iterations for CG and BiCGSTAB — narrow
//!   flops, narrow PCIe streams *and* narrow wire payloads (the
//!   reduced-precision communication leg).
//!
//! Dense rows cover LU, Cholesky, CG and BiCGSTAB at the paper's
//! n = 60000; sparse rows run the Poisson stencils, where the narrow win
//! is the halved CSR value stream and allgather payload.
//!
//! Emits `BENCH_mixed.json` and asserts the acceptance shape:
//! mixed <= f64 on every configuration, strictly smaller on the
//! accelerated arm (the gate is open: SGEMM runs 6x DGEMM and every PCIe /
//! wire byte halves, dwarfing the O(n²) refine overhead), and an *exact*
//! wash on the host arm, where the gate closes and the mixed twin IS the
//! uniform twin — the `--no-mixed` A/B collapses to nothing by
//! construction.
//!
//! ```sh
//! cargo bench --bench mixed
//! ```

use cuplss::accel::{ComputeProfile, DEFAULT_DEVICE_MEM};
use cuplss::bench_harness::model::{
    chol_makespan_gpudirect, chol_makespan_refined, iter_makespan_gpudirect, iter_makespan_mixed,
    lu_makespan_gpudirect, lu_makespan_refined, model_mixed_engaged,
    sparse_iter_makespan_gpudirect, sparse_iter_makespan_mixed, MODEL_REFINE_ITERS,
};
use cuplss::bench_harness::{ModelParams, PAPER_N, PAPER_RANKS};
use cuplss::comm::NetworkModel;
use cuplss::mesh::MeshShape;
use cuplss::solvers::IterMethod;
use cuplss::util::fmt;
use cuplss::workloads::stencil_halo_counts;

struct Row {
    kernel: &'static str,
    engine: &'static str,
    n: usize,
    ranks: usize,
    pr: usize,
    pc: usize,
    f64_secs: f64,
    mixed_secs: f64,
    /// Must mixed win strictly (the dtype x profile gate is open)?
    strict: bool,
}

struct SparseRow {
    stencil: &'static str,
    method: &'static str,
    grid: usize,
    n: usize,
    nnz: usize,
    engine: &'static str,
    ranks: usize,
    f64_secs: f64,
    mixed_secs: f64,
    strict: bool,
}

fn params(ranks: usize, gpu: bool) -> ModelParams {
    ModelParams {
        tile: 256,
        shape: MeshShape::near_square(ranks),
        net: NetworkModel::gigabit_ethernet(),
        engine: if gpu {
            ComputeProfile::gtx280_cublas()
        } else {
            ComputeProfile::q6600_atlas()
        },
        panel_cpu: ComputeProfile::q6600_atlas(),
        swap_fraction: 0.5,
        device_mem: DEFAULT_DEVICE_MEM,
    }
}

fn main() {
    let iters = 100usize;
    let mut rows: Vec<Row> = Vec::new();

    for &ranks in PAPER_RANKS {
        for gpu in [false, true] {
            let p = params(ranks, gpu);
            let (pr, pc) = (p.shape.pr, p.shape.pc);
            let engine = if gpu { "MPI+CUDA" } else { "MPI+ATLAS" };
            let strict = model_mixed_engaged::<f64>(&p);
            let mut push = |kernel, f64_secs: f64, mixed_secs: f64| {
                rows.push(Row {
                    kernel,
                    engine,
                    n: PAPER_N,
                    ranks,
                    pr,
                    pc,
                    f64_secs,
                    mixed_secs,
                    strict,
                });
            };
            push(
                "LU",
                lu_makespan_gpudirect::<f64>(PAPER_N, &p),
                lu_makespan_refined::<f64>(PAPER_N, &p),
            );
            push(
                "Cholesky",
                chol_makespan_gpudirect::<f64>(PAPER_N, &p),
                chol_makespan_refined::<f64>(PAPER_N, &p),
            );
            for (m, name) in [(IterMethod::Cg, "CG"), (IterMethod::Bicgstab, "BiCGSTAB")] {
                push(
                    name,
                    iter_makespan_gpudirect::<f64>(m, PAPER_N, iters, 30, &p),
                    iter_makespan_mixed::<f64>(m, PAPER_N, iters, 30, &p),
                );
            }
        }
    }

    // Poisson-stencil configs: the narrow win is the halved CSR value
    // stream and allgather payload — still gated on the engine profile.
    let mut sparse_rows: Vec<SparseRow> = Vec::new();
    for &ranks in PAPER_RANKS {
        for gpu in [false, true] {
            let p = params(ranks, gpu);
            let engine = if gpu { "MPI+CUDA" } else { "MPI+ATLAS" };
            let strict = model_mixed_engaged::<f64>(&p);
            for (stencil, grid, dim) in [("poisson2d", 512usize, 2u32), ("poisson3d", 64, 3)] {
                let n = grid.pow(dim);
                let h = stencil_halo_counts(grid, dim, p.tile, p.shape.pr);
                for (m, name) in [(IterMethod::Cg, "CG"), (IterMethod::Bicgstab, "BiCGSTAB")] {
                    sparse_rows.push(SparseRow {
                        stencil,
                        method: name,
                        grid,
                        n,
                        nnz: h.total_nnz,
                        engine,
                        ranks,
                        f64_secs: sparse_iter_makespan_gpudirect::<f64>(
                            m,
                            n,
                            h.total_nnz,
                            iters,
                            30,
                            &p,
                        ),
                        mixed_secs: sparse_iter_makespan_mixed::<f64>(
                            m,
                            n,
                            h.total_nnz,
                            iters,
                            30,
                            &p,
                        ),
                        strict,
                    });
                }
            }
        }
    }

    // Table for the terminal.
    let header = ["kernel", "engine", "P", "f64", "mixed", "saved"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kernel.to_string(),
                r.engine.to_string(),
                r.ranks.to_string(),
                fmt::secs(r.f64_secs),
                fmt::secs(r.mixed_secs),
                format!("{:.1}%", (1.0 - r.mixed_secs / r.f64_secs) * 100.0),
            ]
        })
        .collect();
    println!("== Mixed precision vs uniform f64 (n = {PAPER_N}) ==");
    println!("{}", fmt::table(&header, &body));

    // Acceptance shape.
    let check = |label: String, mixed: f64, wide: f64, strict: bool| {
        assert!(
            mixed <= wide * (1.0 + 1e-9),
            "{label}: mixed {mixed} must not exceed f64 {wide}"
        );
        if strict {
            assert!(mixed < wide, "{label}: the gate is open, mixed must strictly win");
        } else {
            assert!(
                (mixed - wide).abs() <= 1e-12 * wide.max(1.0),
                "{label}: the gate is closed, must be an exact wash ({mixed} vs {wide})"
            );
        }
    };
    for r in &rows {
        check(
            format!("{} {} P={}", r.kernel, r.engine, r.ranks),
            r.mixed_secs,
            r.f64_secs,
            r.strict,
        );
    }
    for r in &sparse_rows {
        check(
            format!("{} {} {} P={}", r.stencil, r.method, r.engine, r.ranks),
            r.mixed_secs,
            r.f64_secs,
            r.strict,
        );
    }

    // BENCH_mixed.json (hand-rolled: the offline crate set has no serde).
    let mut json = format!(
        "{{\n  \"network\": \"gigabit_ethernet\",\n  \"tile\": 256,\n  \"iters\": {iters},\n  \
         \"refine_iters\": {MODEL_REFINE_ITERS},\n  \"entries\": [\n"
    );
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"engine\": \"{}\", \"n\": {}, \"ranks\": {}, \
             \"pr\": {}, \"pc\": {}, \"f64_secs\": {:.6e}, \"mixed_secs\": {:.6e}, \
             \"saved_frac\": {:.4}, \"strict\": {}}}{}\n",
            r.kernel,
            r.engine,
            r.n,
            r.ranks,
            r.pr,
            r.pc,
            r.f64_secs,
            r.mixed_secs,
            1.0 - r.mixed_secs / r.f64_secs,
            r.strict,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"sparse\": [\n");
    for (i, r) in sparse_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"stencil\": \"{}\", \"method\": \"{}\", \"grid\": {}, \"n\": {}, \
             \"nnz\": {}, \"engine\": \"{}\", \"ranks\": {}, \"f64_secs\": {:.6e}, \
             \"mixed_secs\": {:.6e}, \"saved_frac\": {:.4}, \"strict\": {}}}{}\n",
            r.stencil,
            r.method,
            r.grid,
            r.n,
            r.nnz,
            r.engine,
            r.ranks,
            r.f64_secs,
            r.mixed_secs,
            1.0 - r.mixed_secs / r.f64_secs,
            r.strict,
            if i + 1 < sparse_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_mixed.json", &json).expect("write BENCH_mixed.json");
    println!(
        "wrote BENCH_mixed.json ({} dense + {} sparse rows); mixed never loses.",
        rows.len(),
        sparse_rows.len()
    );
}
