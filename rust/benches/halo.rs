//! Bench: neighbor-exchange (halo) `pspmv` vs the allgather exchange —
//! the printed number behind the sparse scaling subsystem (`DESIGN.md`
//! §15).
//!
//! For every paper rank count on the gigabit network (host engine — the
//! sparse path has no AOT kernels), evaluates the analytic model for 2-D
//! and 3-D Poisson stencils in two arms that differ **only** in the
//! matvec's wire leg:
//!
//! * **allgather** — the split-phase schedule shipping the whole padded
//!   vector around the column ring each matvec (O(n) wire);
//! * **halo** — the same schedule with the point-to-point ghost exchange:
//!   `neighbors` messages of the exact enumerated coupling surface
//!   (O(surface) wire), overlapped with the same diagonal-block compute.
//!
//! The surface inputs (`ghost_elems`, `neighbors`, `diag_frac`) come from
//! `stencil_halo_counts` — an exact enumeration of the stencil under the
//! round-robin tile distribution, not a closed-form guess.
//!
//! Emits `BENCH_halo.json` and asserts the acceptance shape: halo <=
//! allgather on every configuration, strictly smaller wherever the mesh
//! has more than one process row (P >= 4 here: `near_square` folds P = 2
//! into one row), and an exact wash at one process row (both wires are
//! zero).
//!
//! ```sh
//! cargo bench --bench halo
//! ```

use cuplss::accel::{ComputeProfile, DEFAULT_DEVICE_MEM};
use cuplss::bench_harness::model::{sparse_iter_makespan_halo, sparse_iter_makespan_split};
use cuplss::bench_harness::{ModelParams, PAPER_RANKS};
use cuplss::comm::NetworkModel;
use cuplss::mesh::MeshShape;
use cuplss::solvers::IterMethod;
use cuplss::util::fmt;
use cuplss::workloads::stencil_halo_counts;

struct Row {
    stencil: &'static str,
    method: &'static str,
    grid: usize,
    n: usize,
    nnz: usize,
    ranks: usize,
    pr: usize,
    neighbors: usize,
    ghost_elems: usize,
    diag_frac: f64,
    allgather: f64,
    halo: f64,
    /// Must the halo win strictly (more than one process row)?
    strict: bool,
}

fn params(ranks: usize) -> ModelParams {
    ModelParams {
        tile: 256,
        shape: MeshShape::near_square(ranks),
        net: NetworkModel::gigabit_ethernet(),
        engine: ComputeProfile::q6600_atlas(),
        panel_cpu: ComputeProfile::q6600_atlas(),
        swap_fraction: 0.5,
        device_mem: DEFAULT_DEVICE_MEM,
    }
}

fn main() {
    let iters = 100usize;
    let mut rows: Vec<Row> = Vec::new();

    for &ranks in PAPER_RANKS {
        let p = params(ranks);
        let pr = p.shape.pr;
        for (stencil, grid, dim) in [("poisson2d", 512usize, 2u32), ("poisson3d", 64, 3)] {
            let n = grid.pow(dim);
            let h = stencil_halo_counts(grid, dim, p.tile, pr);
            let diag_frac = h.diag_nnz as f64 / h.total_nnz as f64;
            for (m, name) in [(IterMethod::Cg, "CG"), (IterMethod::Bicgstab, "BiCGSTAB")] {
                rows.push(Row {
                    stencil,
                    method: name,
                    grid,
                    n,
                    nnz: h.total_nnz,
                    ranks,
                    pr,
                    neighbors: h.neighbors,
                    ghost_elems: h.ghost_elems,
                    diag_frac,
                    allgather: sparse_iter_makespan_split::<f64>(
                        m, n, h.total_nnz, iters, diag_frac, &p,
                    ),
                    halo: sparse_iter_makespan_halo::<f64>(
                        m,
                        n,
                        h.total_nnz,
                        iters,
                        diag_frac,
                        h.neighbors,
                        h.ghost_elems,
                        &p,
                    ),
                    strict: pr > 1,
                });
            }
        }
    }

    // Table for the terminal.
    let header =
        ["stencil", "method", "P", "pr", "ghosts", "nbrs", "allgather", "halo", "saved"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.stencil.to_string(),
                r.method.to_string(),
                r.ranks.to_string(),
                r.pr.to_string(),
                r.ghost_elems.to_string(),
                r.neighbors.to_string(),
                fmt::secs(r.allgather),
                fmt::secs(r.halo),
                format!("{:.1}%", (1.0 - r.halo / r.allgather) * 100.0),
            ]
        })
        .collect();
    println!("== Halo exchange vs allgather (sparse matvec wire) ==");
    println!("{}", fmt::table(&header, &body));

    // Acceptance shape.
    for r in &rows {
        assert!(
            r.halo <= r.allgather * (1.0 + 1e-9),
            "{} {} P={}: halo {} > allgather {}",
            r.stencil,
            r.method,
            r.ranks,
            r.halo,
            r.allgather
        );
        if r.strict {
            assert!(
                r.halo < r.allgather,
                "{} {} P={} (pr={}): the halo must strictly win",
                r.stencil,
                r.method,
                r.ranks,
                r.pr
            );
        } else {
            assert!(
                (r.halo - r.allgather).abs() <= 1e-12 * r.allgather.max(1.0),
                "{} {} P={}: one process row must be a wash",
                r.stencil,
                r.method,
                r.ranks
            );
        }
    }

    // BENCH_halo.json (hand-rolled: the offline crate set has no serde).
    let mut json = String::from("{\n  \"network\": \"gigabit_ethernet\",\n  \"entries\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"stencil\": \"{}\", \"method\": \"{}\", \"grid\": {}, \"n\": {}, \
             \"nnz\": {}, \"ranks\": {}, \"pr\": {}, \"neighbors\": {}, \
             \"ghost_elems\": {}, \"diag_frac\": {:.6}, \"allgather_secs\": {:.6e}, \
             \"halo_secs\": {:.6e}, \"saved_frac\": {:.4}}}{}\n",
            r.stencil,
            r.method,
            r.grid,
            r.n,
            r.nnz,
            r.ranks,
            r.pr,
            r.neighbors,
            r.ghost_elems,
            r.diag_frac,
            r.allgather,
            r.halo,
            1.0 - r.halo / r.allgather,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_halo.json", &json).expect("write BENCH_halo.json");
    println!("wrote BENCH_halo.json ({} entries); the halo never loses.", rows.len());
}
