//! Ablation E4 — *why* is the CUDA arm's gain modest?  The paper blames
//! "GPU memory contention on GPU device and the communication overhead
//! incurred by the MPI processes".  This bench isolates both terms:
//!
//! 1. **PCIe sweep** — Figure-4 LU speedup at P = 16 as the host<->device
//!    bandwidth varies from 1 GB/s to "infinite" (resident data).  The gap
//!    between 5.5 GB/s (PCIe 2.0) and `inf` is exactly the paper's
//!    "contention" loss.
//! 2. **Network alpha sweep** — the same point as MPI latency varies from
//!    Gigabit Ethernet (50 µs) down to an ideal network, quantifying the
//!    "MPI processes act as synchronizing points" loss.
//!
//! ```sh
//! cargo bench --bench ablation_overheads
//! ```

use cuplss::accel::ComputeProfile;
use cuplss::bench_harness::model::{iter_makespan, lu_makespan, ModelParams};
use cuplss::bench_harness::PAPER_N;
use cuplss::comm::NetworkModel;
use cuplss::mesh::MeshShape;
use cuplss::solvers::IterMethod;
use cuplss::util::fmt;

fn params(engine: ComputeProfile, net: NetworkModel) -> ModelParams {
    ModelParams {
        tile: 256,
        shape: MeshShape::near_square(16),
        net,
        engine,
        panel_cpu: ComputeProfile::q6600_atlas(),
        swap_fraction: 0.5,
        device_mem: cuplss::accel::DEFAULT_DEVICE_MEM,
    }
}

fn main() {
    let n = PAPER_N;
    let net = NetworkModel::gigabit_ethernet();
    let base_cpu = lu_makespan::<f32>(
        n,
        &ModelParams {
            shape: MeshShape::new(1, 1),
            ..params(ComputeProfile::q6600_atlas(), net)
        },
    );

    println!("== E4.1: PCIe bandwidth sweep (LU, P=16, n={n}, SP) ==");
    let mut rows = Vec::new();
    let mut prev = 0.0;
    for (label, bw) in [
        ("1 GB/s", 1.0e9),
        ("2.5 GB/s", 2.5e9),
        ("5.5 GB/s (PCIe 2.0, paper)", 5.5e9),
        ("12 GB/s", 12.0e9),
        ("resident (no transfers)", 0.0),
    ] {
        let mut gpu = ComputeProfile::gtx280_cublas();
        gpu.pcie_bw = bw;
        let ms = lu_makespan::<f32>(n, &params(gpu, net));
        let speedup = base_cpu / ms;
        rows.push(vec![label.to_string(), fmt::secs(ms), format!("{speedup:.2}")]);
        assert!(
            speedup > prev * 0.999,
            "more PCIe bandwidth must not hurt: {label}"
        );
        prev = speedup;
    }
    println!("{}", fmt::table(&["PCIe", "makespan", "speedup vs serial CPU"], &rows));

    println!("== E4.2: MPI latency sweep (BiCGSTAB 100 iters, P=16, n={n}, SP) ==");
    let mut rows = Vec::new();
    for (label, alpha) in [
        ("200 µs (congested)", 200e-6),
        ("50 µs (Gigabit, paper)", 50e-6),
        ("5 µs (fast interconnect)", 5e-6),
        ("0 (ideal)", 0.0),
    ] {
        let mut net_v = net;
        net_v.alpha = alpha;
        if alpha == 0.0 {
            net_v = NetworkModel::ideal();
        }
        let gpu = params(ComputeProfile::gtx280_cublas(), net_v);
        let cpu1 = ModelParams {
            shape: MeshShape::new(1, 1),
            ..params(ComputeProfile::q6600_atlas(), net_v)
        };
        let ms = iter_makespan::<f32>(IterMethod::Bicgstab, n, 100, 30, &gpu);
        let base = iter_makespan::<f32>(IterMethod::Bicgstab, n, 100, 30, &cpu1);
        rows.push(vec![label.to_string(), fmt::secs(ms), format!("{:.2}", base / ms)]);
    }
    println!("{}", fmt::table(&["MPI latency", "makespan", "speedup vs serial CPU"], &rows));

    // Headline decomposition: how much of the ideal CUDA speedup do the two
    // overheads eat at the paper's operating point?
    let paper = lu_makespan::<f32>(n, &params(ComputeProfile::gtx280_cublas(), net));
    let mut resident = ComputeProfile::gtx280_cublas();
    resident.pcie_bw = 0.0;
    let no_pcie = lu_makespan::<f32>(n, &params(resident, net));
    let no_net = lu_makespan::<f32>(n, &params(ComputeProfile::gtx280_cublas(), NetworkModel::ideal()));
    println!("LU P=16 overhead shares: PCIe transfers add {:.0}% runtime, network adds {:.0}%",
        (paper / no_pcie - 1.0) * 100.0,
        (paper / no_net - 1.0) * 100.0,
    );
    println!("E4 checks passed.");
}
