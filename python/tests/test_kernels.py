"""L1 Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps block-aligned shapes and both dtypes; every property is a
straight assert_allclose against the oracle, so a failure indicts the kernel.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm as gemm_k
from compile.kernels import gemv as gemv_k
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

B = 128  # MXU-native Pallas block; all library shapes are multiples of it

DTYPES = [jnp.float32, jnp.float64]


def _tol(dtype):
    return dict(rtol=2e-4, atol=2e-4) if dtype == jnp.float32 else dict(rtol=1e-10, atol=1e-10)


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------- GEMM


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 256, 256), (128, 256, 384)])
def test_gemm_matches_ref(dtype, m, n, k):
    rng = np.random.default_rng(seed=m * 7 + n * 11 + k)
    a, b = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
    got = gemm_k.gemm(a, b)
    np.testing.assert_allclose(got, ref.ref_gemm(a, b), **_tol(dtype))


@settings(max_examples=20, deadline=None)
@given(
    mi=st.integers(1, 3),
    ni=st.integers(1, 3),
    ki=st.integers(1, 3),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_property(mi, ni, ki, dt, seed):
    """Block-aligned shape sweep: gemm == ref for any (mi,ni,ki)*128 shape."""
    m, n, k = mi * B, ni * B, ki * B
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, (m, k), dt), _rand(rng, (k, n), dt)
    np.testing.assert_allclose(gemm_k.gemm(a, b), ref.ref_gemm(a, b), **_tol(dt))


@settings(max_examples=15, deadline=None)
@given(
    mi=st.integers(1, 2),
    ki=st.integers(1, 3),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_update_property(mi, ki, dt, seed):
    m = mi * B
    k = ki * B
    rng = np.random.default_rng(seed)
    c = _rand(rng, (m, m), dt)
    a = _rand(rng, (m, k), dt)
    b = _rand(rng, (k, m), dt)
    got = gemm_k.gemm_update(c, a, b)
    np.testing.assert_allclose(got, ref.ref_gemm_update(c, a, b), **_tol(dt))


@settings(max_examples=15, deadline=None)
@given(
    mi=st.integers(1, 2),
    ki=st.integers(1, 3),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_acc_property(mi, ki, dt, seed):
    m = mi * B
    k = ki * B
    rng = np.random.default_rng(seed)
    c = _rand(rng, (m, m), dt)
    a = _rand(rng, (m, k), dt)
    b = _rand(rng, (k, m), dt)
    got = gemm_k.gemm_acc(c, a, b)
    np.testing.assert_allclose(got, ref.ref_gemm_acc(c, a, b), **_tol(dt))


def test_gemm_acc_zero_ab_is_identity():
    rng = np.random.default_rng(3)
    c = _rand(rng, (128, 128), jnp.float32)
    z = jnp.zeros((128, 128), jnp.float32)
    np.testing.assert_allclose(gemm_k.gemm_acc(c, z, z), c, rtol=0, atol=0)


def test_gemm_block_shape_invariance():
    """Different Pallas block shapes must give identical results."""
    rng = np.random.default_rng(0)
    a = _rand(rng, (256, 256), jnp.float32)
    b = _rand(rng, (256, 256), jnp.float32)
    base = gemm_k.gemm(a, b, bm=128, bn=128, bk=128)
    for bm, bn, bk in [(256, 256, 256), (128, 256, 128), (256, 128, 256)]:
        got = gemm_k.gemm(a, b, bm=bm, bn=bn, bk=bk)
        # different K-block walks sum in different orders -> f32 rounding
        np.testing.assert_allclose(got, base, rtol=1e-3, atol=1e-3)


def test_gemm_rejects_unaligned():
    a = jnp.zeros((100, 128), jnp.float32)
    b = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(ValueError, match="multiples"):
        gemm_k.gemm(a, b)


def test_gemm_update_zero_ab_is_identity():
    rng = np.random.default_rng(1)
    c = _rand(rng, (128, 128), jnp.float32)
    z = jnp.zeros((128, 128), jnp.float32)
    np.testing.assert_allclose(gemm_k.gemm_update(c, z, z), c, rtol=0, atol=0)


def test_gemm_identity():
    rng = np.random.default_rng(2)
    a = _rand(rng, (256, 256), jnp.float64)
    eye = jnp.eye(256, dtype=jnp.float64)
    np.testing.assert_allclose(gemm_k.gemm(a, eye), a, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(gemm_k.gemm(eye, a), a, rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------- GEMV


@settings(max_examples=20, deadline=None)
@given(
    mi=st.integers(1, 4),
    ki=st.integers(1, 4),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemv_property(mi, ki, dt, seed):
    m, k = mi * B, ki * B
    rng = np.random.default_rng(seed)
    a, x = _rand(rng, (m, k), dt), _rand(rng, (k,), dt)
    np.testing.assert_allclose(gemv_k.gemv(a, x), ref.ref_gemv(a, x), **_tol(dt))


@settings(max_examples=15, deadline=None)
@given(
    mi=st.integers(1, 2),
    ki=st.integers(1, 3),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemv_update_property(mi, ki, dt, seed):
    m, k = mi * B, ki * B
    rng = np.random.default_rng(seed)
    y = _rand(rng, (m,), dt)
    a, x = _rand(rng, (m, k), dt), _rand(rng, (k,), dt)
    got = gemv_k.gemv_update(y, a, x)
    np.testing.assert_allclose(got, ref.ref_gemv_update(y, a, x), **_tol(dt))


@settings(max_examples=15, deadline=None)
@given(
    mi=st.integers(1, 2),
    ki=st.integers(1, 3),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemv_acc_property(mi, ki, dt, seed):
    m, k = mi * B, ki * B
    rng = np.random.default_rng(seed)
    y = _rand(rng, (m,), dt)
    a, x = _rand(rng, (m, k), dt), _rand(rng, (k,), dt)
    got = gemv_k.gemv_acc(y, a, x)
    np.testing.assert_allclose(got, ref.ref_gemv_acc(y, a, x), **_tol(dt))


def test_gemv_acc_zero_a_is_identity():
    rng = np.random.default_rng(7)
    y = _rand(rng, (256,), jnp.float64)
    z = jnp.zeros((256, 256), jnp.float64)
    x = _rand(rng, (256,), jnp.float64)
    np.testing.assert_allclose(gemv_k.gemv_acc(y, z, x), y, rtol=0, atol=0)


def test_gemv_t_acc_ref_matches_transpose():
    # The L2 builder lowers gemv_t_acc as gemv_acc(y, a.T, x); pin the
    # reference relation the rust op relies on.
    rng = np.random.default_rng(8)
    y = _rand(rng, (256,), jnp.float64)
    a = _rand(rng, (256, 256), jnp.float64)
    x = _rand(rng, (256,), jnp.float64)
    np.testing.assert_allclose(
        gemv_k.gemv_acc(y, a.T, x), ref.ref_gemv_t_acc(y, a, x), rtol=1e-12, atol=1e-12
    )


def test_gemv_identity():
    rng = np.random.default_rng(3)
    x = _rand(rng, (256,), jnp.float64)
    eye = jnp.eye(256, dtype=jnp.float64)
    np.testing.assert_allclose(gemv_k.gemv(eye, x), x, rtol=1e-12, atol=1e-12)


def test_gemv_rejects_unaligned():
    a = jnp.zeros((128, 100), jnp.float32)
    x = jnp.zeros((100,), jnp.float32)
    with pytest.raises(ValueError, match="multiples"):
        gemv_k.gemv(a, x)
