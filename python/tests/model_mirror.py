"""Faithful Python transcription of `rust/src/bench_harness/model.rs` (plus
the cost/network models it rides on) — the no-toolchain verification oracle
for the residency PR.

Every function mirrors its rust namesake term by term (same operation
order, f64 arithmetic), so the inequalities the rust benches assert
(`cargo bench --bench overlap` / `--bench residency`) can be checked here,
and the committed `BENCH_*.json` artifacts can be generated without a rust
toolchain.  If a rust-side formula changes, change it here in the same way.
"""

import math
from dataclasses import dataclass

# ---------------------------------------------------------------------------
# accel/costmodel.rs
# ---------------------------------------------------------------------------

DEFAULT_DEVICE_MEM = 1 << 30  # accel/residency.rs: GTX 280 = 1 GiB

BLAS3 = "blas3"
BLAS2 = "blas2"
BLAS1 = "blas1"

_BLAS3_OPS = {
    "gemm", "gemm_acc", "gemm_update", "gemm_nt_update", "potrf",
    "trsm_llu", "trsm_ru", "trsm_rlt",
}
_BLAS2_OPS = {
    "gemv", "gemv_t", "gemv_update", "gemv_acc", "gemv_t_acc",
    "trsv_lu", "trsv_l", "trsv_u", "trsv_lt",
}


def op_class(op):
    if op in _BLAS3_OPS:
        return BLAS3
    if op in _BLAS2_OPS:
        return BLAS2
    return BLAS1


@dataclass(frozen=True)
class ComputeProfile:
    name: str
    flops3_sp: float
    flops3_dp: float
    mem_bw: float
    launch: float
    pcie_bw: float

    def flops3(self, bytes_per_elem):
        return self.flops3_sp if bytes_per_elem == 4 else self.flops3_dp

    def op_cost_total(self, klass, flops, touched_bytes, stream_bytes, b):
        """Total seconds of one op: compute + launch + transfer."""
        rate3 = self.flops3(b)
        if klass == BLAS3:
            compute = flops / rate3
        else:
            compute = max(flops / (rate3 / 8.0), touched_bytes / self.mem_bw)
        transfer = stream_bytes / self.pcie_bw if self.pcie_bw > 0.0 else 0.0
        return compute + self.launch + transfer


def gtx280_cublas():
    return ComputeProfile("gtx280-cublas", 360e9, 60e9, 120e9, 12e-6, 5.5e9)


def q6600_atlas():
    return ComputeProfile("q6600-atlas", 13.5e9, 6.7e9, 4.0e9, 0.2e-6, 0.0)


# ---------------------------------------------------------------------------
# accel/engine.rs — op tables
# ---------------------------------------------------------------------------


def op_flops(op, t):
    if op == "gemm":
        return 2 * t**3
    if op in ("gemm_update", "gemm_nt_update", "gemm_acc"):
        return 2 * t**3 + t * t
    if op in ("gemv", "gemv_t"):
        return 2 * t * t
    if op in ("gemv_update", "gemv_acc", "gemv_t_acc"):
        return 2 * t * t + t
    if op == "potrf":
        return t**3 // 3
    if op in ("trsm_llu", "trsm_ru", "trsm_rlt"):
        return t**3
    if op in ("trsv_lu", "trsv_l", "trsv_u", "trsv_lt"):
        return t * t
    if op in ("dot", "axpy"):
        return 2 * t
    raise KeyError(op)


def op_operand_elems(op, t):
    t2 = t * t
    table = {
        "gemm": ([t2, t2], t2),
        "gemm_acc": ([t2, t2, t2], t2),
        "gemm_update": ([t2, t2, t2], t2),
        "gemm_nt_update": ([t2, t2, t2], t2),
        "gemv": ([t2, t], t),
        "gemv_t": ([t2, t], t),
        "gemv_update": ([t, t2, t], t),
        "gemv_acc": ([t, t2, t], t),
        "gemv_t_acc": ([t, t2, t], t),
        "potrf": ([t2], t2),
        "trsm_llu": ([t2, t2], t2),
        "trsm_ru": ([t2, t2], t2),
        "trsm_rlt": ([t2, t2], t2),
        "trsv_lu": ([t2, t], t),
        "trsv_l": ([t2, t], t),
        "trsv_u": ([t2, t], t),
        "trsv_lt": ([t2, t], t),
    }
    return table[op]


def op_touched_elems(op, t):
    ins, out = op_operand_elems(op, t)
    return sum(ins), out


def tile_op_cost_total(profile, op, tile, b):
    tin, tout = op_touched_elems(op, tile)
    return profile.op_cost_total(
        op_class(op), op_flops(op, tile), (tin + tout) * b, (tin + tout) * b, b
    )


def spmv_cost_total(profile, nnz, nrows, nout, b):
    bytes_ = nnz * (2 * b + 4) + (nrows + 1) * 4 + nout * b
    return profile.op_cost_total(BLAS2, 2 * nnz, bytes_, bytes_, b)


# ---------------------------------------------------------------------------
# comm/model.rs + mesh/mod.rs + dist ceil_div
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NetworkModel:
    alpha: float
    beta: float
    alpha_local: float

    def p2p_secs(self, bytes_):
        return self.alpha + bytes_ * self.beta


def gigabit_ethernet():
    return NetworkModel(50e-6, 8.5e-9, 0.5e-6)


def near_square(p):
    pr = int(math.sqrt(p))
    while pr > 1 and p % pr != 0:
        pr -= 1
    pr = max(pr, 1)
    return pr, p // pr


def ceil_div(a, b):
    return -(-a // b)


# ---------------------------------------------------------------------------
# bench_harness/model.rs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelParams:
    tile: int
    pr: int
    pc: int
    net: NetworkModel
    engine: ComputeProfile
    panel_cpu: ComputeProfile
    swap_fraction: float
    device_mem: int = DEFAULT_DEVICE_MEM

    def op(self, name, b):
        return tile_op_cost_total(self.engine, name, self.tile, b)

    def blas1(self, length, b):
        return self.panel_cpu.op_cost_total(
            BLAS1, 2 * length, 3 * length * b, 3 * length * b, b
        )

    def msg(self, elems, b):
        return self.net.p2p_secs(elems * b)

    def tree(self, p, elems, b):
        if p <= 1:
            return 0.0
        rounds = (p - 1).bit_length()
        return rounds * self.msg(elems, b)

    def ring(self, p, elems, b):
        if p <= 1:
            return 0.0
        return (p - 1) * self.msg(elems, b)

    def op_resident(self, name, b):
        tin, tout = op_touched_elems(name, self.tile)
        return self.engine.op_cost_total(
            op_class(name), op_flops(name, self.tile), (tin + tout) * b, 0, b
        )

    def xfer(self, elems, b):
        if self.engine.pcie_bw > 0.0:
            return elems * b / self.engine.pcie_bw
        return 0.0

    def resident_extra(self, my_rows, my_cols, my_tiles, first_step,
                       invalidated, clamp_calls, panel_copies, b):
        """ModelParams::resident_extra — shared residency pricing of the
        LU/Cholesky/SUMMA twins (see the rust doc comment)."""
        t2 = self.tile * self.tile
        ws = (my_tiles + panel_copies * (my_rows + my_cols)) * t2 * b
        c_factor = 2.0 if (ws > self.device_mem or first_step) else invalidated
        extra = (my_rows + my_cols) * t2 + c_factor * (my_tiles * t2)
        return self.xfer(int(min(extra, clamp_calls * my_tiles * t2)), b)

    def blas1_fused(self, length, streams, flops_per_elem, b):
        nbytes = streams * length * b
        flops = flops_per_elem * length
        own = self.engine.op_cost_total(BLAS1, flops, nbytes, nbytes, b)
        if self.engine.pcie_bw <= 0.0:
            return own
        host = self.panel_cpu.op_cost_total(BLAS1, flops, nbytes, nbytes, b)
        return min(own, host)


def lu_step_parts(n, p, b, resident=False):
    """Per-step (panel_cpu, panel_comm, pre, update_compute, update_pcie):
    the trailing leg split so the resident twin sums the shares while the
    prefetch twin takes their max (rust lu_step_parts)."""
    t = p.tile
    kt = ceil_div(n, t)
    pr, pc = p.pr, p.pc
    t2 = t * t
    parts = []
    for k in range(kt):
        mk = kt - k
        trailing = mk - 1
        panel_cpu = 0.0
        panel_comm = 0.0
        pre = 0.0
        update = 0.0
        update_pcie = 0.0
        remote_tiles = mk - ceil_div(mk, pr)
        if pr > 1:
            panel_comm += (ceil_div(mk, pr) + remote_tiles) * p.msg(t2, b)
        flops = (mk * t) * t * t
        panel_cpu += p.panel_cpu.op_cost_total(
            BLAS3, flops, mk * t2 * b, mk * t2 * b, b
        )
        panel_comm += p.tree(pr * pc, t, b)
        if pr > 1 and p.swap_fraction > 0.0:
            seg = ceil_div(kt, pc) * t
            cross = (pr - 1) / pr
            pre += p.swap_fraction * cross * t * p.msg(seg, b)
        if trailing > 0:
            pre += p.tree(pc, t2, b)
            pre += ceil_div(trailing, pc) * p.op("trsm_llu", b)
            panel_comm += ceil_div(trailing, pr) * p.tree(pc, t2, b)
            pre += ceil_div(trailing, pc) * p.tree(pr, t2, b)
            my_rows = ceil_div(trailing, pr)
            my_cols = ceil_div(trailing, pc)
            my_tiles = my_rows * my_cols
            if resident and p.engine.pcie_bw > 0.0:
                update = my_tiles * p.op_resident("gemm_update", b)
                update_pcie = p.resident_extra(
                    my_rows, my_cols, my_tiles, k == 0, p.swap_fraction, 4, 1, b
                )
            else:
                update = my_tiles * p.op("gemm_update", b)
        parts.append((panel_cpu, panel_comm, pre, update, update_pcie))
    return parts


def _fold_update(parts, combine):
    """rust fold_update: fold the split trailing leg with `combine`."""
    return [(cpu, comm, pre, combine(uc, up)) for cpu, comm, pre, uc, up in parts]


def trsv_makespan(n, p, b):
    t = p.tile
    kt = ceil_div(n, t)
    pr, pc = p.pr, p.pc
    total = 0.0
    for k in range(kt):
        others = kt - k - 1
        total += p.op("trsv_lu", b)
        total += p.tree(pr * pc, t, b)
        my_rows = ceil_div(others, pr)
        total += my_rows * (p.tree(pc, t * t, b) + p.op("gemv_update", b))
    return total


def trsv_resident_makespan(n, p, b):
    """rust trsv_resident_makespan: substitution against already-broadcast
    resident factors — the my_rows·tree(pc, t²) factor-tile wire leg drops;
    only the diagonal solve, the solved-chunk bcast and the local
    gemv_updates recur."""
    t = p.tile
    kt = ceil_div(n, t)
    pr, pc = p.pr, p.pc
    total = 0.0
    for k in range(kt):
        others = kt - k - 1
        total += p.op("trsv_lu", b)
        total += p.tree(pr * pc, t, b)
        my_rows = ceil_div(others, pr)
        total += my_rows * p.op("gemv_update", b)
    return total


def lu_makespan(n, p, b):
    total = sum(sum(part) for part in lu_step_parts(n, p, b))
    return total + trsv_makespan(n, p, b) * 2.0


def _lu_lookahead_assembly(parts):
    kt = len(parts)
    total = parts[0][0] + parts[0][1]
    for k, (_, _, pre, update) in enumerate(parts):
        if k + 1 < kt:
            next_cpu, next_comm = parts[k + 1][0], parts[k + 1][1]
        else:
            next_cpu, next_comm = 0.0, 0.0
        total += pre + next_cpu + max(update, next_comm)
    return total


def _add(a, b):
    return a + b


def lu_makespan_lookahead(n, p, b):
    return (
        _lu_lookahead_assembly(_fold_update(lu_step_parts(n, p, b), _add))
        + trsv_makespan(n, p, b) * 2.0
    )


def lu_makespan_resident(n, p, b):
    return (
        _lu_lookahead_assembly(_fold_update(lu_step_parts(n, p, b, resident=True), _add))
        + trsv_makespan(n, p, b) * 2.0
    )


def lu_makespan_prefetch(n, p, b):
    """rust lu_makespan_prefetch: the trailing PCIe extra rides the
    copy-engine timeline under the gemm stream (max instead of +)."""
    return (
        _lu_lookahead_assembly(_fold_update(lu_step_parts(n, p, b, resident=True), max))
        + trsv_makespan(n, p, b) * 2.0
    )


def lu_prefetch_headroom(n, p, b):
    """rust lu_prefetch_headroom: did residency leave PCIe on the critical
    path (some step's resident trailing leg exceeds the next panel comm)?"""
    parts = lu_step_parts(n, p, b, resident=True)
    kt = len(parts)
    for k in range(kt):
        _, _, _, uc, up = parts[k]
        next_comm = parts[k + 1][1] if k + 1 < kt else 0.0
        if uc > 0.0 and up > 0.0 and uc + up > next_comm:
            return True
    return False


def summa_makespan(n, p, b, overlapped):
    t = p.tile
    kt = ceil_div(n, t)
    my_rows = ceil_div(kt, p.pr)
    my_cols = ceil_div(kt, p.pc)
    bcast = my_rows * p.tree(p.pc, t * t, b) + my_cols * p.tree(p.pr, t * t, b)
    compute = (my_rows * my_cols) * (p.op("gemm", b) + p.blas1(t * t, b))
    if overlapped:
        return bcast + (kt - 1) * max(bcast, compute) + compute
    return kt * (bcast + compute)


def summa_makespan_resident(n, p, b, overlapped):
    return _summa_makespan_cached(n, p, b, overlapped, _add)


def summa_makespan_prefetch(n, p, b, overlapped):
    return _summa_makespan_cached(n, p, b, overlapped, max)


def _summa_makespan_cached(n, p, b, overlapped, combine):
    t = p.tile
    t2 = t * t
    kt = ceil_div(n, t)
    my_rows = ceil_div(kt, p.pr)
    my_cols = ceil_div(kt, p.pc)
    my_tiles = my_rows * my_cols
    bcast = my_rows * p.tree(p.pc, t2, b) + my_cols * p.tree(p.pr, t2, b)
    gacc = my_tiles * p.op_resident("gemm_acc", b)

    def step_extra(k):
        return p.resident_extra(my_rows, my_cols, my_tiles, k == 0, 0.0, 3, 2, b)

    if overlapped:
        total = bcast
        for k in range(kt):
            compute = combine(gacc, step_extra(k))
            total += max(compute, bcast) if k + 1 < kt else compute
        return total
    return sum(bcast + combine(gacc, step_extra(k)) for k in range(kt))


def chol_factor_impl(n, p, b, resident=False, combine=_add):
    """rust chol_factor_impl: the factor loop alone (no substitutions, no
    transpose traffic) — split out so the batched solve twin can reuse it."""
    kt = ceil_div(n, p.tile)
    total = 0.0
    for k in range(kt):
        # Term-level accumulation (NOT a per-step regroup): the committed
        # artifacts pin these bits, and (x + a) + b != x + (a + b).
        total = chol_step_cost(n, p, b, k, resident, combine, total)
    return total


def chol_step_cost(n, p, b, k, resident, combine, total):
    """rust chol_step_cost: one panel step of the Cholesky factor loop,
    accumulated onto `total` term by term — threading the accumulator keeps
    the full-loop float association identical to the pre-split code while
    letting the fault-recovery twins price replay spans `[a, b)`."""
    t = p.tile
    kt = ceil_div(n, t)
    pr, pc = p.pr, p.pc
    t2 = t * t
    trailing = kt - k - 1
    total += p.op("potrf", b)
    total += p.tree(pr, t2, b)
    total += ceil_div(trailing, pr) * p.op("trsm_rlt", b)
    if trailing == 0:
        return total
    total += ceil_div(trailing, pr) * p.tree(pc, t2, b)
    total += ceil_div(trailing, pc) * p.tree(pr, t2, b)
    my_rows = ceil_div(trailing, pr)
    my_cols = ceil_div(trailing, pc)
    my_tiles = ceil_div(my_rows * my_cols, 2)
    if resident and p.engine.pcie_bw > 0.0:
        total += combine(
            my_tiles * p.op_resident("gemm_nt_update", b),
            p.resident_extra(my_rows, my_cols, my_tiles, k == 0, 0.0, 4, 1, b),
        )
    else:
        total += my_tiles * p.op("gemm_nt_update", b)
    return total


def chol_transpose_traffic(n, p, b):
    """rust chol_transpose_traffic: the one `ptranspose` redistribution."""
    t = p.tile
    kt = ceil_div(n, t)
    my_tiles = ceil_div(kt, p.pr) * ceil_div(kt, p.pc)
    return my_tiles * p.msg(t * t, b)


def chol_makespan(n, p, b, resident=False, combine=_add):
    # Same association order as before the split: (factor + trsv*2) + traffic.
    return (
        chol_factor_impl(n, p, b, resident, combine)
        + trsv_makespan(n, p, b) * 2.0
        + chol_transpose_traffic(n, p, b)
    )


def chol_makespan_resident(n, p, b):
    return chol_makespan(n, p, b, resident=True)


def chol_makespan_prefetch(n, p, b):
    return chol_makespan(n, p, b, resident=True, combine=max)


def iter_makespan(method, n, iters, restart, p, b):
    t = p.tile
    kt = ceil_div(n, t)
    pr, pc = p.pr, p.pc
    my_rows = ceil_div(kt, pr)
    my_cols = ceil_div(kt, pc)
    vec_elems = my_rows * t
    matvec = (
        p.ring(pr, vec_elems, b)
        + (my_rows * my_cols) * p.op("gemv_acc", b)
        + 2.0 * p.tree(pc, vec_elems, b)
    )
    matvec_t = (
        (my_rows * my_cols) * p.op("gemv_t_acc", b)
        + my_cols * p.tree(pr, t, b)
        + p.ring(pc, vec_elems, b)
    )
    dot = my_rows * p.blas1(t, b) + 2.0 * p.tree(pr, 1, b)
    vop = my_rows * p.blas1(t, b)
    if method == "cg":
        per_iter = matvec + 2.0 * dot + 3.0 * vop
    elif method == "pipecg":
        per_iter = matvec + 2.0 * p.tree(pr, 2, b) + 11.0 * vop
    elif method == "bicg":
        per_iter = matvec + matvec_t + 3.0 * dot + 7.0 * vop
    elif method == "bicgstab":
        per_iter = 2.0 * matvec + 5.0 * dot + 6.0 * vop
    elif method == "gmres":
        m = max(restart, 1)
        per_iter = matvec + (m / 2.0 + 1.0) * (dot + vop) + 2.0 * vop
    else:
        raise KeyError(method)
    return iters * per_iter


def iter_makespan_fused(method, n, iters, restart, p, b):
    return _iter_makespan_cached(method, n, iters, restart, p, b, _add)


def iter_makespan_prefetch(method, n, iters, restart, p, b):
    """rust iter_makespan_prefetch: the matvec's surviving PCIe rides the
    copy-engine timeline (max instead of +)."""
    return _iter_makespan_cached(method, n, iters, restart, p, b, max)


def dense_matvec_terms(p, n, b):
    """rust dense_matvec_terms: (gemv compute stream, per-matvec PCIe,
    one-time A load) under the residency flow."""
    t = p.tile
    kt = ceil_div(n, t)
    my_rows = ceil_div(kt, p.pr)
    my_cols = ceil_div(kt, p.pc)
    my_tiles = my_rows * my_cols
    a_fits = my_tiles * t * t * b <= p.device_mem
    if p.engine.pcie_bw <= 0.0:
        return my_tiles * p.op("gemv_acc", b), 0.0, 0.0
    compute = my_tiles * p.op_resident("gemv_acc", b)
    if a_fits:
        return (
            compute,
            p.xfer((my_cols + my_rows) * t, b),
            p.xfer(my_tiles * t * t, b),
        )
    return compute, my_tiles * p.xfer(t * t + 3 * t, b), 0.0


def _iter_makespan_cached(method, n, iters, restart, p, b, combine):
    t = p.tile
    kt = ceil_div(n, t)
    pr, pc = p.pr, p.pc
    my_rows = ceil_div(kt, pr)
    vec_elems = my_rows * t

    gemv_stream, matvec_pcie, a_load = dense_matvec_terms(p, n, b)
    matvec = (
        p.ring(pr, vec_elems, b)
        + combine(gemv_stream, matvec_pcie)
        + 2.0 * p.tree(pc, vec_elems, b)
    )
    dot = my_rows * p.blas1(t, b) + 2.0 * p.tree(pr, 1, b)
    vop = my_rows * p.blas1(t, b)
    axpy_norm2 = p.blas1_fused(vec_elems, 3, 4, b) + 2.0 * p.tree(pr, 1, b)
    axpy_norm2_dot = p.blas1_fused(vec_elems, 4, 6, b) + 2.0 * p.tree(pr, 2, b)
    norm2_dot = p.blas1_fused(vec_elems, 2, 4, b) + 2.0 * p.tree(pr, 2, b)
    xpay = p.blas1_fused(vec_elems, 3, 2, b)

    if iters == 0:
        return 0.0
    if method == "cg":
        per_iter = matvec + dot + vop + axpy_norm2 + xpay
    elif method == "pipecg":
        per_iter = (
            matvec
            + p.blas1_fused(vec_elems, 2, 4, b)
            + 2.0 * p.tree(pr, 2, b)
            + 3.0 * xpay
            + 3.0 * vop
        )
    elif method == "bicgstab":
        per_iter = (
            2.0 * matvec + dot + axpy_norm2 + norm2_dot + 3.0 * vop
            + axpy_norm2_dot + xpay
        )
    else:
        return iter_makespan(method, n, iters, restart, p, b)
    return iters * per_iter + a_load


def sparse_cg_terms(n, nnz, p, b):
    t = p.tile
    kt = ceil_div(n, t)
    pr = p.pr
    my_rows = ceil_div(kt, pr)
    vec_elems = my_rows * t
    local_nnz = ceil_div(nnz, pr)
    ring = p.ring(pr, vec_elems, b)
    spmv = spmv_cost_total(p.engine, local_nnz, vec_elems, vec_elems, b)
    dot = my_rows * p.blas1(t, b) + 2.0 * p.tree(pr, 1, b)
    vop = my_rows * p.blas1(t, b)
    return ring, spmv, dot, vop


def sparse_iter_makespan(method, n, nnz, iters, restart, p, b):
    t = p.tile
    kt = ceil_div(n, t)
    pr = p.pr
    my_rows = ceil_div(kt, pr)
    vec_elems = my_rows * t
    full_elems = kt * t
    local_nnz = ceil_div(nnz, pr)
    ring, spmv, dot, vop = sparse_cg_terms(n, nnz, p, b)
    matvec = ring + spmv
    matvec_t = spmv_cost_total(
        p.engine, local_nnz, vec_elems, full_elems, b
    ) + 2.0 * p.tree(pr, full_elems, b)
    if method == "cg":
        per_iter = matvec + 2.0 * dot + 3.0 * vop
    elif method == "pipecg":
        per_iter = matvec + 2.0 * p.tree(pr, 2, b) + 11.0 * vop
    elif method == "bicg":
        per_iter = matvec + matvec_t + 3.0 * dot + 7.0 * vop
    elif method == "bicgstab":
        per_iter = 2.0 * matvec + 5.0 * dot + 6.0 * vop
    elif method == "gmres":
        m = max(restart, 1)
        per_iter = matvec + (m / 2.0 + 1.0) * (dot + vop) + 2.0 * vop
    else:
        raise KeyError(method)
    return iters * per_iter


def sparse_iter_makespan_fused(method, n, nnz, iters, restart, p, b):
    t = p.tile
    kt = ceil_div(n, t)
    pr = p.pr
    my_rows = ceil_div(kt, pr)
    vec_elems = my_rows * t
    ring, spmv, dot, vop = sparse_cg_terms(n, nnz, p, b)
    matvec = ring + spmv
    axpy_norm2 = p.blas1_fused(vec_elems, 3, 4, b) + 2.0 * p.tree(pr, 1, b)
    axpy_norm2_dot = p.blas1_fused(vec_elems, 4, 6, b) + 2.0 * p.tree(pr, 2, b)
    norm2_dot = p.blas1_fused(vec_elems, 2, 4, b) + 2.0 * p.tree(pr, 2, b)
    xpay = p.blas1_fused(vec_elems, 3, 2, b)
    if method == "cg":
        per_iter = matvec + dot + vop + axpy_norm2 + xpay
    elif method == "pipecg":
        per_iter = (
            matvec
            + p.blas1_fused(vec_elems, 2, 4, b)
            + 2.0 * p.tree(pr, 2, b)
            + 3.0 * xpay
            + 3.0 * vop
        )
    elif method == "bicgstab":
        per_iter = (
            2.0 * matvec + dot + axpy_norm2 + norm2_dot + 3.0 * vop
            + axpy_norm2_dot + xpay
        )
    else:
        return sparse_iter_makespan(method, n, nnz, iters, restart, p, b)
    return iters * per_iter


def sparse_iter_makespan_prefetch(method, n, nnz, iters, restart, p, b):
    """Identical to the fused twin by definition: sparse operands run
    host-side, the copy engine is idle (rust sparse_iter_makespan_prefetch)."""
    return sparse_iter_makespan_fused(method, n, nnz, iters, restart, p, b)


def halo_wire(p, neighbors, ghost_elems, b):
    """rust halo_wire: `neighbors` point-to-point ghost segments of
    ceil(ghost_elems / neighbors) scalars; zero with no neighbors."""
    if neighbors == 0:
        return 0.0
    return neighbors * p.msg(ceil_div(ghost_elems, neighbors), b)


def _sparse_fused_with_wire(method, n, nnz, iters, diag_frac, wire, p, b):
    """rust sparse_fused_with_wire: max(wire, diag) + off per matvec, the
    fused BLAS-1 chain for the rest — CG and BiCGSTAB arms only."""
    t = p.tile
    kt = ceil_div(n, t)
    pr = p.pr
    my_rows = ceil_div(kt, pr)
    vec_elems = my_rows * t
    _ring, spmv, dot, vop = sparse_cg_terms(n, nnz, p, b)
    matvec = max(wire, diag_frac * spmv) + (1.0 - diag_frac) * spmv
    axpy_norm2 = p.blas1_fused(vec_elems, 3, 4, b) + 2.0 * p.tree(pr, 1, b)
    axpy_norm2_dot = p.blas1_fused(vec_elems, 4, 6, b) + 2.0 * p.tree(pr, 2, b)
    norm2_dot = p.blas1_fused(vec_elems, 2, 4, b) + 2.0 * p.tree(pr, 2, b)
    xpay = p.blas1_fused(vec_elems, 3, 2, b)
    if method == "cg":
        per_iter = matvec + dot + vop + axpy_norm2 + xpay
    elif method == "bicgstab":
        per_iter = (
            2.0 * matvec + dot + axpy_norm2 + norm2_dot + 3.0 * vop
            + axpy_norm2_dot + xpay
        )
    else:
        raise KeyError(method)
    return iters * per_iter


def sparse_iter_makespan_split(method, n, nnz, iters, diag_frac, p, b):
    """rust sparse_iter_makespan_split: the allgather arm of the halo
    bench — wire leg = the column-comm ring of the whole padded vector."""
    ring, _spmv, _dot, _vop = sparse_cg_terms(n, nnz, p, b)
    return _sparse_fused_with_wire(method, n, nnz, iters, diag_frac, ring, p, b)


def sparse_iter_makespan_halo(method, n, nnz, iters, diag_frac,
                              neighbors, ghost_elems, p, b):
    """rust sparse_iter_makespan_halo: wire leg = halo_wire over the exact
    enumerated coupling surface; everything else shared with the split
    twin, so halo can never model slower than allgather."""
    wire = halo_wire(p, neighbors, ghost_elems, b)
    return _sparse_fused_with_wire(method, n, nnz, iters, diag_frac, wire, p, b)


def sparse_cg_split_makespan(n, nnz, iters, diag_frac, p, b):
    ring, spmv, dot, vop = sparse_cg_terms(n, nnz, p, b)
    matvec = max(ring, diag_frac * spmv) + (1.0 - diag_frac) * spmv
    return iters * (matvec + 2.0 * dot + 3.0 * vop)


def sparse_pipecg_overlap_makespan(n, nnz, iters, diag_frac, p, b):
    ring, spmv, _dot, vop = sparse_cg_terms(n, nnz, p, b)
    matvec = max(ring, diag_frac * spmv) + (1.0 - diag_frac) * spmv
    reduction = 2.0 * p.tree(p.pr, 2, b)
    return iters * (max(matvec, reduction) + 11.0 * vop)


# ---------------------------------------------------------------------------
# workloads/stencil.rs — nnz closed forms + the exact halo-surface counts
# ---------------------------------------------------------------------------


def poisson1d_nnz(g):
    return 3 * g - 2


def poisson2d_nnz(g):
    return 5 * g * g - 4 * g


def poisson3d_nnz(g):
    return 7 * g**3 - 6 * g * g


def stencil_strides(g, dim):
    """rust stencil_strides: row i's off-diagonal couplings sit at i ± g^k."""
    return [g**k for k in range(dim)]


def stencil_halo_counts(g, dim, tile, pr):
    """Verbatim port of rust workloads::stencil_halo_counts — the exact
    O(n·dim) enumeration of a dim-D Poisson stencil's coupling surface
    under the round-robin tile-row distribution (tile row ti on process
    row ti mod pr).  Max fields are worst-case over process rows."""
    n = g**dim
    strides = stencil_strides(g, dim)

    def owner(x):
        return (x // tile) % pr

    ghost = [0] * pr
    send = [0] * pr
    pair = [[False] * pr for _ in range(pr)]
    diag_nnz = n  # every diagonal entry is owned by its own row
    total_nnz = n
    for j in range(n):
        oj = owner(j)
        # Process rows referencing column j from a remote row i = j -+ s.
        refs = []
        for s in strides:
            # i = j - s references j = i + s: valid when i's axis
            # coordinate is below the far face.
            if j >= s and (j - s) // s % g < g - 1:
                oi = owner(j - s)
                total_nnz += 1
                if oi != oj:
                    if oi not in refs:
                        refs.append(oi)
                else:
                    diag_nnz += 1
            # i = j + s references j = i - s: valid when i's axis
            # coordinate is above the near face.
            if j + s < n and (j + s) // s % g > 0:
                oi = owner(j + s)
                total_nnz += 1
                if oi != oj:
                    if oi not in refs:
                        refs.append(oi)
                else:
                    diag_nnz += 1
        for r in refs:
            ghost[r] += 1
            pair[r][oj] = True
            pair[oj][r] = True
        send[oj] += len(refs)
    neighbors = max(sum(1 for q in range(pr) if pair[r][q]) for r in range(pr))
    return {
        "ghost_elems": max(ghost),
        "send_elems": max(send),
        "neighbors": neighbors,
        "diag_nnz": diag_nnz,
        "total_nnz": total_nnz,
    }


# ---------------------------------------------------------------------------
# accel/engine.rs RHS-panel ops + bench_harness/model.rs batched twins
# ---------------------------------------------------------------------------


def panel_op_flops(op, t, k):
    """rust panel_op_flops: k columns' worth of the single-column flops."""
    return k * op_flops(op, t)


def panel_operand_elems(op, t, k):
    """rust panel_operand_elems: the tile-sized operand is touched once for
    all k columns; vector-length operands scale by k."""
    t2 = t * t
    ins, out = op_operand_elems(op, t)
    ins = [e if e == t2 else e * k for e in ins]
    return ins, (out if out == t2 else out * k)


def panel_op_cost_total(profile, op, tile, k, b):
    """rust panel_op_cost .total(): k columns, one launch, tile streamed
    once.  k = 1 prices exactly like tile_op_cost_total."""
    ins, out = panel_operand_elems(op, tile, k)
    touched = (sum(ins) + out) * b
    return profile.op_cost_total(
        op_class(op), panel_op_flops(op, tile, k), touched, touched, b
    )


def _panel_op(p, name, k, b):
    """rust ModelParams::panel_op."""
    return panel_op_cost_total(p.engine, name, p.tile, k, b)


def trsm_makespan(n, k, p, b):
    """rust trsm_makespan: one RHS-panel triangular substitution — per step
    one panel trsv, one world bcast of the k·t chunk, per owned column
    tile ONE broadcast (amortized over columns) + one panel gemv_update.
    trsm_makespan(n, 1, p) == trsv_makespan(n, p) exactly."""
    t = p.tile
    kt = ceil_div(n, t)
    pr, pc = p.pr, p.pc
    total = 0.0
    for s in range(kt):
        others = kt - s - 1
        total += _panel_op(p, "trsv_lu", k, b)
        total += p.tree(pr * pc, k * t, b)
        my_rows = ceil_div(others, pr)
        total += my_rows * (p.tree(pc, t * t, b) + _panel_op(p, "gemv_update", k, b))
    return total


def lu_solve_makespan_batched(n, k, p, b):
    """rust lu_solve_makespan_batched: one factorization + two RHS-panel
    substitutions.  k = 1 reproduces lu_makespan bit for bit."""
    total = sum(sum(part) for part in lu_step_parts(n, p, b))
    return total + trsm_makespan(n, k, p, b) * 2.0


def chol_solve_makespan_batched(n, k, p, b):
    """rust chol_solve_makespan_batched: one factorization, ONE transpose
    redistribution, two RHS-panel substitutions.  k = 1 == chol_makespan."""
    return (
        chol_factor_impl(n, p, b)
        + trsm_makespan(n, k, p, b) * 2.0
        + chol_transpose_traffic(n, p, b)
    )


def cg_makespan_batched(n, k, iters, p, b):
    """rust cg_makespan_batched: blocked CG — k-column collectives, one
    panel gemv_acc per owned A tile, k-lane dots, column-batched vector
    recurrences.  k = 1 reproduces the iter_makespan CG arm bit for bit."""
    t = p.tile
    kt = ceil_div(n, t)
    pr, pc = p.pr, p.pc
    my_rows = ceil_div(kt, pr)
    my_cols = ceil_div(kt, pc)
    vec_elems = my_rows * t
    matvec = (
        p.ring(pr, k * vec_elems, b)
        + (my_rows * my_cols) * _panel_op(p, "gemv_acc", k, b)
        + 2.0 * p.tree(pc, k * vec_elems, b)
    )
    dot = k * (my_rows * p.blas1(t, b)) + 2.0 * p.tree(pr, k, b)
    vop = my_rows * p.blas1(k * t, b)
    return iters * (matvec + 2.0 * dot + 3.0 * vop)


def bicgstab_makespan_batched(n, k, iters, p, b):
    """rust bicgstab_makespan_batched: blocked BiCGSTAB — the same
    column-batched legs as cg_makespan_batched assembled with the BiCGSTAB
    iteration shape (two matvecs, five dots, six vector ops).  k = 1
    reproduces the iter_makespan BiCGSTAB arm bit for bit."""
    t = p.tile
    kt = ceil_div(n, t)
    pr, pc = p.pr, p.pc
    my_rows = ceil_div(kt, pr)
    my_cols = ceil_div(kt, pc)
    vec_elems = my_rows * t
    matvec = (
        p.ring(pr, k * vec_elems, b)
        + (my_rows * my_cols) * _panel_op(p, "gemv_acc", k, b)
        + 2.0 * p.tree(pc, k * vec_elems, b)
    )
    dot = k * (my_rows * p.blas1(t, b)) + 2.0 * p.tree(pr, k, b)
    vop = my_rows * p.blas1(k * t, b)
    return iters * (2.0 * matvec + 5.0 * dot + 6.0 * vop)


# ---------------------------------------------------------------------------
# bench_harness/model.rs — GPUDirect wire twins (DESIGN.md §16)
# ---------------------------------------------------------------------------


def wire_payload(p, elems, b):
    """rust wire_payload: one device-dirty wire payload of `elems` scalars
    -> (stage, residual).  (0, 0) on host profiles."""
    stage = p.xfer(elems, b)
    if stage <= 0.0:
        return 0.0, 0.0
    return stage, max(stage - p.msg(elems, b), 0.0)


def _lu_wire_legs(n, p, b):
    """rust lu_wire_legs: U12 column broadcasts every step + the non-owner
    panel-gather legs from step 1 on, all under pr > 1."""
    t2 = p.tile * p.tile
    kt = ceil_div(n, p.tile)
    pr, pc = p.pr, p.pc
    s1, r1 = wire_payload(p, t2, b)
    stage = residual = 0.0
    for k in range(kt):
        mk = kt - k
        trailing = mk - 1
        if pr > 1:
            if k >= 1:
                remote_tiles = mk - ceil_div(mk, pr)
                stage += remote_tiles * s1
                residual += remote_tiles * r1
            stage += ceil_div(trailing, pc) * s1
            residual += ceil_div(trailing, pc) * r1
    return stage, residual


def lu_wire_stage(n, p, b):
    return _lu_wire_legs(n, p, b)[0]


def lu_makespan_gpudirect(n, p, b):
    return lu_makespan_prefetch(n, p, b) + _lu_wire_legs(n, p, b)[1]


def _chol_wire_legs(n, p, b):
    """rust chol_wire_legs: the L11 column broadcast (pr > 1) and the panel
    row broadcasts (pc > 1) every step."""
    t2 = p.tile * p.tile
    kt = ceil_div(n, p.tile)
    pr, pc = p.pr, p.pc
    s1, r1 = wire_payload(p, t2, b)
    stage = residual = 0.0
    for k in range(kt):
        trailing = kt - k - 1
        if pr > 1:
            stage += s1
            residual += r1
        if pc > 1:
            stage += ceil_div(trailing, pr) * s1
            residual += ceil_div(trailing, pr) * r1
    return stage, residual


def chol_wire_stage(n, p, b):
    return _chol_wire_legs(n, p, b)[0]


def chol_makespan_gpudirect(n, p, b):
    return chol_makespan_prefetch(n, p, b) + _chol_wire_legs(n, p, b)[1]


def summa_wire_stage(n, p, b):
    """rust summa_wire_stage: zero — the broadcast panels are read-only,
    host-clean inputs."""
    return 0.0


def summa_makespan_gpudirect(n, p, b, overlapped):
    return summa_makespan_prefetch(n, p, b, overlapped)


def _iter_wire_legs(method, n, iters, p, b):
    """rust iter_wire_legs: the matvec's device-dirty y_part allreduce —
    once per matvec, twice per BiCGSTAB iteration, nothing at pc = 1."""
    pr, pc = p.pr, p.pc
    if pc <= 1:
        return 0.0, 0.0
    vec_elems = ceil_div(ceil_div(n, p.tile), pr) * p.tile
    s1, r1 = wire_payload(p, vec_elems, b)
    if method in ("cg", "pipecg"):
        matvecs = 1.0
    elif method == "bicgstab":
        matvecs = 2.0
    else:
        return 0.0, 0.0
    per = iters * matvecs
    return per * s1, per * r1


def iter_wire_stage(method, n, iters, p, b):
    return _iter_wire_legs(method, n, iters, p, b)[0]


def iter_makespan_gpudirect(method, n, iters, restart, p, b):
    return (
        iter_makespan_prefetch(method, n, iters, restart, p, b)
        + _iter_wire_legs(method, n, iters, p, b)[1]
    )


def sparse_iter_wire_stage(n, nnz, p, b):
    """rust sparse_iter_wire_stage: zero — sparse operands run host-side,
    every ghost segment is host-clean."""
    return 0.0


def sparse_iter_makespan_gpudirect(method, n, nnz, iters, restart, p, b):
    return sparse_iter_makespan_prefetch(method, n, nnz, iters, restart, p, b)


# ---------------------------------------------------------------------------
# bench_harness/model.rs — mixed-precision twins (DESIGN.md §17)
# ---------------------------------------------------------------------------

MODEL_REFINE_ITERS = 3  # model.rs MODEL_REFINE_ITERS


def mixed_advantage(profile):
    """accel/costmodel.rs ComputeProfile::mixed_advantage: narrow arithmetic
    only pays when the engine streams over PCIe and SGEMM outruns DGEMM."""
    return profile.pcie_bw > 0.0 and profile.flops3_sp > profile.flops3_dp


def mixed_capable(b):
    """lib.rs mixed_capable::<S>: S::Lo is strictly narrower than S — true
    only for f64 (f32 is its own Lo)."""
    return b == 8


def model_mixed_engaged(p, b):
    """model.rs model_mixed_engaged::<S>: the dtype x profile gate."""
    return mixed_capable(b) and mixed_advantage(p.engine)


def demote_pass(p, elems, b):
    """model.rs demote_pass::<S>: one streaming read-wide/write-narrow sweep
    on the host (S::Lo is 4 bytes for every capable S)."""
    return p.panel_cpu.op_cost_total(BLAS1, elems, elems * (b + 4), 0, b)


def local_matrix_elems(n, p):
    """model.rs local_matrix_elems: owned tile payload of the widest rank."""
    kt = ceil_div(n, p.tile)
    return ceil_div(kt, p.pr) * ceil_div(kt, p.pc) * p.tile * p.tile


def refine_sweep(n, p):
    """model.rs refine_sweep::<S>: one wide residual/correction sweep —
    r = b − A·x (ring row-broadcast of x + owned-tile GEMVs + column-tree
    reduction), the inner solve is charged separately, then the axpy-class
    update and convergence allreduce.  All legs at S::Hi (8 bytes)."""
    hb = 8
    t = p.tile
    kt = ceil_div(n, t)
    my_rows = ceil_div(kt, p.pr)
    my_cols = ceil_div(kt, p.pc)
    vec_elems = my_rows * t
    tile_gemv = p.panel_cpu.op_cost_total(
        BLAS2, 2 * t * t, (t * t + 2 * t) * hb, 0, hb
    )
    return (
        p.ring(p.pr, vec_elems, hb)
        + (my_rows * my_cols) * tile_gemv
        + 2.0 * p.tree(p.pc, vec_elems, hb)
        + 2.0 * p.blas1(vec_elems, hb)
        + 2.0 * p.tree(p.pr, 1, hb)
    )


def lu_makespan_refined(n, p, b):
    """model.rs lu_makespan_refined::<S>: narrow factorization + wide
    refinement, never worse than the uniform gpudirect twin."""
    uniform = lu_makespan_gpudirect(n, p, b)
    if not model_mixed_engaged(p, b):
        return uniform
    mixed = (
        demote_pass(p, local_matrix_elems(n, p), b)
        + lu_makespan_gpudirect(n, p, 4)
        + MODEL_REFINE_ITERS
        * (refine_sweep(n, p) + 2.0 * trsv_resident_makespan(n, p, 4))
    )
    return min(mixed, uniform)


def chol_makespan_refined(n, p, b):
    uniform = chol_makespan_gpudirect(n, p, b)
    if not model_mixed_engaged(p, b):
        return uniform
    mixed = (
        demote_pass(p, local_matrix_elems(n, p), b)
        + chol_makespan_gpudirect(n, p, 4)
        + MODEL_REFINE_ITERS
        * (refine_sweep(n, p) + 2.0 * trsv_resident_makespan(n, p, 4))
    )
    return min(mixed, uniform)


def iter_makespan_mixed(method, n, iters, restart, p, b):
    """model.rs iter_makespan_mixed::<S>: f32-storage/f64-accumulate Krylov
    — only CG and BiCGSTAB have mixed kernels."""
    uniform = iter_makespan_gpudirect(method, n, iters, restart, p, b)
    if not model_mixed_engaged(p, b) or method not in ("cg", "bicgstab"):
        return uniform
    mixed = demote_pass(p, local_matrix_elems(n, p), b) + iter_makespan_gpudirect(
        method, n, iters, restart, p, 4
    )
    return min(mixed, uniform)


def sparse_iter_makespan_mixed(method, n, nnz, iters, restart, p, b):
    """model.rs sparse_iter_makespan_mixed::<S>: the demote pass covers the
    owned CSR value slice; the narrow win is the halved value stream and
    allgather payload."""
    uniform = sparse_iter_makespan_gpudirect(method, n, nnz, iters, restart, p, b)
    if not model_mixed_engaged(p, b) or method not in ("cg", "bicgstab"):
        return uniform
    mixed = demote_pass(p, ceil_div(nnz, p.pr), b) + sparse_iter_makespan_gpudirect(
        method, n, nnz, iters, restart, p, 4
    )
    return min(mixed, uniform)


# ---------------------------------------------------------------------------
# bench_harness/model.rs — fault-tolerance twins (DESIGN.md §18)
# ---------------------------------------------------------------------------


def ckpt_leg(n, p, b):
    """model.rs ckpt_leg::<S>: one direct-method checkpoint — D2H of the
    rank's local tile share.  0 on host profiles."""
    return p.xfer(local_matrix_elems(n, p), b)


def n_panels(n, p):
    """model.rs n_panels: panel count of an n x n factorisation."""
    return ceil_div(n, p.tile)


def n_checkpoints(panels, every):
    """model.rs n_checkpoints: one per `every` panels, panel 0 included."""
    return ceil_div(panels, max(every, 1))


def lu_span(n, p, b, start, stop):
    """model.rs lu_span: replay span of LU panels [start, stop) — the
    identical per-step terms of the resident/prefetch (gpudirect) flow."""
    parts = lu_step_parts(n, p, b, resident=True)
    return sum(
        cpu + comm + pre + max(uc, up)
        for cpu, comm, pre, uc, up in parts[start:stop]
    )


def chol_span(n, p, b, start, stop):
    """model.rs chol_span: replay span of Cholesky panels [start, stop)."""
    acc = 0.0
    for k in range(start, stop):
        acc = chol_step_cost(n, p, b, k, True, max, acc)
    return acc


def lu_makespan_ckpt(n, every, p, b):
    """model.rs lu_makespan_ckpt::<S>: the gpudirect twin plus one D2H leg
    per checkpoint — fault-free overhead is exactly the leg sum."""
    return (
        lu_makespan_gpudirect(n, p, b)
        + n_checkpoints(n_panels(n, p), every) * ckpt_leg(n, p, b)
    )


def chol_makespan_ckpt(n, every, p, b):
    return (
        chol_makespan_gpudirect(n, p, b)
        + n_checkpoints(n_panels(n, p), every) * ckpt_leg(n, p, b)
    )


def lu_recovery_full(n, crash, reboot, p, b):
    """model.rs lu_recovery_full::<S>: fault-free run + reboot + a full
    replay of panels [0, crash)."""
    return lu_makespan_gpudirect(n, p, b) + reboot + lu_span(n, p, b, 0, crash)


def lu_recovery_ckpt(n, every, crash, reboot, p, b):
    """model.rs lu_recovery_ckpt::<S>: the checkpoint-taxed run + reboot +
    one restore leg + replay of only [last_checkpoint, crash)."""
    e = max(every, 1)
    last = (crash // e) * e
    return (
        lu_makespan_ckpt(n, every, p, b)
        + reboot
        + ckpt_leg(n, p, b)
        + lu_span(n, p, b, last, crash)
    )


def chol_recovery_full(n, crash, reboot, p, b):
    return chol_makespan_gpudirect(n, p, b) + reboot + chol_span(n, p, b, 0, crash)


def chol_recovery_ckpt(n, every, crash, reboot, p, b):
    e = max(every, 1)
    last = (crash // e) * e
    return (
        chol_makespan_ckpt(n, every, p, b)
        + reboot
        + ckpt_leg(n, p, b)
        + chol_span(n, p, b, last, crash)
    )


def krylov_snap_leg(method, n, p, b):
    """model.rs krylov_snap_leg::<S>: CG/BiCGSTAB snapshot three local
    vector blocks (x, r, p), GMRES snapshots x alone; 0 on host profiles
    and for methods without a fault-tolerant variant."""
    vecs = {"cg": 3, "bicgstab": 3, "gmres": 1}.get(method, 0)
    vec_elems = ceil_div(ceil_div(n, p.tile), p.pr) * p.tile
    return vecs * p.xfer(vec_elems, b)


def krylov_snap_period(method, every, restart):
    """model.rs krylov_snap_period: GMRES snapshots at every restart cycle
    (the policy's period is ignored), CG/BiCGSTAB honor `every`."""
    return max(restart, 1) if method == "gmres" else max(every, 1)


def iter_makespan_ckpt(method, n, iters, restart, every, p, b):
    """model.rs iter_makespan_ckpt::<S>: one snapshot leg per period,
    iteration 0 included."""
    period = krylov_snap_period(method, every, restart)
    return (
        iter_makespan_gpudirect(method, n, iters, restart, p, b)
        + n_checkpoints(iters, period) * krylov_snap_leg(method, n, p, b)
    )


def iter_recovery_full(method, n, iters, restart, crash, reboot, p, b):
    return (
        iter_makespan_gpudirect(method, n, iters, restart, p, b)
        + reboot
        + iter_makespan_gpudirect(method, n, crash, restart, p, b)
    )


def iter_recovery_ckpt(method, n, iters, restart, every, crash, reboot, p, b):
    period = krylov_snap_period(method, every, restart)
    last = (crash // period) * period
    return (
        iter_makespan_ckpt(method, n, iters, restart, every, p, b)
        + reboot
        + krylov_snap_leg(method, n, p, b)
        + iter_makespan_gpudirect(method, n, crash - last, restart, p, b)
    )


# ---------------------------------------------------------------------------
# serve/mod.rs — request stream, batching and the scheduling timeline
# ---------------------------------------------------------------------------


def demo_stream(length, base_n):
    """rust serve::demo_stream: groups of four share an operator, methods
    cycle lu/cg/chol/bicgstab across groups, sizes cycle base_n·{1,2,3},
    tolerances alternate, arrivals tick every 2 ms.  Pure arithmetic."""
    out = []
    for i in range(length):
        group = i // 4
        method = ("lu", "cg", "chol", "bicgstab")[group % 4]
        workload = "spd" if method in ("chol", "cg") else "diagdom"
        out.append({
            "id": i,
            "workload": workload,
            "n": base_n * (1 + group % 3),
            "method": method,
            "tol": 1e-6 if i % 2 == 0 else 1e-8,
            "arrival": 0.002 * i,
        })
    return out


def _compatible(a, b):
    return a["workload"] == b["workload"] and a["n"] == b["n"] and a["method"] == b["method"]


def form_batches(requests, rhs_batch=8, batching=True):
    """rust serve::form_batches: FIFO, merge only consecutive compatible
    requests, cap rhs_batch (1 when batching is off)."""
    cap = max(rhs_batch, 1) if batching else 1
    batches = []
    for i in range(len(requests)):
        if batches and len(batches[-1]) < cap and _compatible(
            requests[batches[-1][0]], requests[i]
        ):
            batches[-1].append(i)
        else:
            batches.append([i])
    return batches


def schedule(requests, rhs_batch, batching, price, factor_cache=False):
    """rust serve::schedule: a batch starts when the cluster is free AND
    its last member has arrived; latency = finish − arrival.  `price`
    maps (member list, factor_cached) to the batch makespan, where
    `factor_cached` mirrors the scheduler's seen-set over
    (workload, n, method): a direct-method batch whose operator an earlier
    batch already factored (with the cache on).  Returns
    ((arrival, finish) per request in stream order, batch count,
    factor-cache hit count)."""
    batches = form_batches(requests, rhs_batch, batching)
    clock = 0.0
    outcomes = []
    seen = set()
    hits = 0
    for batch in batches:
        members = [requests[i] for i in batch]
        head = members[0]
        cached = False
        if factor_cache and head["method"] in ("lu", "chol"):
            key = (head["workload"], head["n"], head["method"])
            cached = key in seen
            seen.add(key)
        if cached:
            hits += 1
        makespan = price(members, cached)
        ready = 0.0
        for r in members:
            ready = max(ready, r["arrival"])
        start = max(clock, ready)
        finish = start + makespan
        clock = finish
        outcomes.extend((r["arrival"], finish) for r in members)
    return outcomes, len(batches), hits


def throughput(outcomes):
    """rust ServeReport::throughput."""
    if not outcomes:
        return 0.0
    first = min(a for a, _ in outcomes)
    last = 0.0
    for _, f in outcomes:
        last = max(last, f)
    return len(outcomes) / (last - first) if last > first else 0.0


def latency_percentile(outcomes, q):
    """rust ServeReport::latency_percentile (nearest-rank)."""
    lats = sorted(f - a for a, f in outcomes)
    if not lats:
        return 0.0
    idx = min(max(math.ceil(q * len(lats)), 1), len(lats)) - 1
    return lats[idx]


def latency_max(outcomes):
    m = 0.0
    for a, f in outcomes:
        m = max(m, f - a)
    return m


# ---------------------------------------------------------------------------
# Bench-row generation (mirrors rust/benches/{overlap,residency}.rs)
# ---------------------------------------------------------------------------

PAPER_RANKS = (1, 2, 4, 8, 16)
PAPER_N = 60_000
STENCIL_DIAG_FRAC = 0.9


def params(ranks, gpu, swap_fraction=0.5):
    pr, pc = near_square(ranks)
    return ModelParams(
        tile=256,
        pr=pr,
        pc=pc,
        net=gigabit_ethernet(),
        engine=gtx280_cublas() if gpu else q6600_atlas(),
        panel_cpu=q6600_atlas(),
        swap_fraction=swap_fraction,
    )


def overlap_rows():
    """Rows of BENCH_overlap.json (rust/benches/overlap.rs)."""
    grid = 1_000
    sparse_n, nnz = grid * grid, 5 * grid * grid - 4 * grid
    iters = 100
    rows = []
    for ranks in PAPER_RANKS:
        for gpu in (False, True):
            p = params(ranks, gpu)
            engine = "MPI+CUDA" if gpu else "MPI+ATLAS"
            rows.append((
                "LU", engine, PAPER_N, ranks,
                lu_makespan(PAPER_N, p, 4), lu_makespan_lookahead(PAPER_N, p, 4),
            ))
            rows.append((
                "SUMMA", engine, PAPER_N, ranks,
                summa_makespan(PAPER_N, p, 4, False), summa_makespan(PAPER_N, p, 4, True),
            ))
            if not gpu:
                rows.append((
                    "sparse CG", engine, sparse_n, ranks,
                    sparse_iter_makespan("cg", sparse_n, nnz, iters, 30, p, 8),
                    sparse_cg_split_makespan(sparse_n, nnz, iters, STENCIL_DIAG_FRAC, p, 8),
                ))
                rows.append((
                    "pipelined CG", engine, sparse_n, ranks,
                    sparse_iter_makespan("pipecg", sparse_n, nnz, iters, 30, p, 8),
                    sparse_pipecg_overlap_makespan(
                        sparse_n, nnz, iters, STENCIL_DIAG_FRAC, p, 8
                    ),
                ))
    return rows


def residency_rows():
    """Rows of BENCH_residency.json (rust/benches/residency.rs): each row is
    (kernel, engine, n, ranks, streaming, cached, strict)."""
    grid = 1_000
    sparse_n, nnz = grid * grid, 5 * grid * grid - 4 * grid
    iters = 100
    rows = []
    for ranks in PAPER_RANKS:
        for gpu in (False, True):
            p = params(ranks, gpu)
            engine = "MPI+CUDA" if gpu else "MPI+ATLAS"
            rows.append((
                "LU", engine, PAPER_N, ranks,
                lu_makespan_lookahead(PAPER_N, p, 4),
                lu_makespan_resident(PAPER_N, p, 4),
                gpu,
            ))
            rows.append((
                "Cholesky", engine, PAPER_N, ranks,
                chol_makespan(PAPER_N, p, 4),
                chol_makespan_resident(PAPER_N, p, 4),
                gpu,
            ))
            rows.append((
                "SUMMA", engine, PAPER_N, ranks,
                summa_makespan(PAPER_N, p, 4, True),
                summa_makespan_resident(PAPER_N, p, 4, True),
                True,
            ))
            for m, name in (("cg", "CG"), ("pipecg", "pipelined CG"),
                            ("bicgstab", "BiCGSTAB")):
                rows.append((
                    name, engine, PAPER_N, ranks,
                    iter_makespan(m, PAPER_N, iters, 30, p, 4),
                    iter_makespan_fused(m, PAPER_N, iters, 30, p, 4),
                    True,
                ))
            if not gpu:
                for m, name in (("cg", "sparse CG"), ("pipecg", "sparse pipelined CG")):
                    rows.append((
                        name, engine, sparse_n, ranks,
                        sparse_iter_makespan(m, sparse_n, nnz, iters, 30, p, 8),
                        sparse_iter_makespan_fused(m, sparse_n, nnz, iters, 30, p, 8),
                        True,
                    ))
    return rows


def prefetch_rows():
    """Rows of BENCH_prefetch.json (rust/benches/prefetch.rs): each row is
    (kernel, engine, n, ranks, streaming, resident, prefetch, strict) where
    `strict` means prefetch must beat resident strictly (PCIe was on the
    compute path)."""
    grid = 1_000
    sparse_n, nnz = grid * grid, 5 * grid * grid - 4 * grid
    iters = 100
    rows = []
    for ranks in PAPER_RANKS:
        for gpu in (False, True):
            p = params(ranks, gpu)
            engine = "MPI+CUDA" if gpu else "MPI+ATLAS"
            rows.append((
                "LU", engine, PAPER_N, ranks,
                lu_makespan_lookahead(PAPER_N, p, 4),
                lu_makespan_resident(PAPER_N, p, 4),
                lu_makespan_prefetch(PAPER_N, p, 4),
                # Strict only where residency left PCIe on the critical
                # path: the lookahead already hides the trailing leg behind
                # panel comm at large rank counts.
                gpu and lu_prefetch_headroom(PAPER_N, p, 4),
            ))
            rows.append((
                "Cholesky", engine, PAPER_N, ranks,
                chol_makespan(PAPER_N, p, 4),
                chol_makespan_resident(PAPER_N, p, 4),
                chol_makespan_prefetch(PAPER_N, p, 4),
                gpu,
            ))
            rows.append((
                "SUMMA", engine, PAPER_N, ranks,
                summa_makespan(PAPER_N, p, 4, True),
                summa_makespan_resident(PAPER_N, p, 4, True),
                summa_makespan_prefetch(PAPER_N, p, 4, True),
                gpu,
            ))
            for m, name in (("cg", "CG"), ("pipecg", "pipelined CG"),
                            ("bicgstab", "BiCGSTAB")):
                rows.append((
                    name, engine, PAPER_N, ranks,
                    iter_makespan(m, PAPER_N, iters, 30, p, 4),
                    iter_makespan_fused(m, PAPER_N, iters, 30, p, 4),
                    iter_makespan_prefetch(m, PAPER_N, iters, 30, p, 4),
                    gpu,
                ))
            if not gpu:
                for m, name in (("cg", "sparse CG"), ("pipecg", "sparse pipelined CG")):
                    rows.append((
                        name, engine, sparse_n, ranks,
                        sparse_iter_makespan(m, sparse_n, nnz, iters, 30, p, 8),
                        sparse_iter_makespan_fused(m, sparse_n, nnz, iters, 30, p, 8),
                        sparse_iter_makespan_prefetch(m, sparse_n, nnz, iters, 30, p, 8),
                        False,
                    ))
    return rows


SERVE_ITERS = 100
SERVE_REQUESTS = 16
SERVE_BASE_N = 20_000
SERVE_RANKS = 16


def serving_entries():
    """Amortization-sweep rows of BENCH_serving.json
    (rust/benches/serving.rs): each row is
    (kernel, engine, n, ranks, k, single, looped, batched)."""
    iters = SERVE_ITERS
    rows = []
    for ranks in PAPER_RANKS:
        for gpu in (False, True):
            p = params(ranks, gpu)
            engine = "MPI+CUDA" if gpu else "MPI+ATLAS"
            singles = (
                ("TRSM", trsm_makespan(PAPER_N, 1, p, 4)),
                ("LU solve", lu_solve_makespan_batched(PAPER_N, 1, p, 4)),
                ("Cholesky solve", chol_solve_makespan_batched(PAPER_N, 1, p, 4)),
                ("blocked CG", cg_makespan_batched(PAPER_N, 1, iters, p, 4)),
            )
            for k in (1, 2, 4, 8):
                for kernel, single in singles:
                    if kernel == "TRSM":
                        batched = trsm_makespan(PAPER_N, k, p, 4)
                    elif kernel == "LU solve":
                        batched = lu_solve_makespan_batched(PAPER_N, k, p, 4)
                    elif kernel == "Cholesky solve":
                        batched = chol_solve_makespan_batched(PAPER_N, k, p, 4)
                    else:
                        batched = cg_makespan_batched(PAPER_N, k, iters, p, 4)
                    rows.append((
                        kernel, engine, PAPER_N, ranks, k,
                        single, k * single, batched,
                    ))
    return rows


def _serve_price(p, members):
    """rust serving.rs model_batch_cost: direct methods ride the batched
    solve twins, CG and BiCGSTAB their blocked sweeps, and anything without
    a batched twin prices as k looped singles."""
    head = members[0]
    k = len(members)
    n = head["n"]
    m = head["method"]
    if m == "lu":
        return lu_solve_makespan_batched(n, k, p, 4)
    if m == "chol":
        return chol_solve_makespan_batched(n, k, p, 4)
    if m == "cg":
        return cg_makespan_batched(n, k, SERVE_ITERS, p, 4)
    if m == "bicgstab":
        return bicgstab_makespan_batched(n, k, SERVE_ITERS, p, 4)
    return k * iter_makespan(m, n, SERVE_ITERS, 30, p, 4)


def serving_rows():
    """Serving-scenario rows of BENCH_serving.json: each row is
    (engine, ranks, requests, base_n, batching, batches, throughput,
    p50, p95, max)."""
    stream = demo_stream(SERVE_REQUESTS, SERVE_BASE_N)
    rows = []
    for gpu in (False, True):
        p = params(SERVE_RANKS, gpu)
        engine = "MPI+CUDA" if gpu else "MPI+ATLAS"
        for batching in (True, False):
            outcomes, nbatches, _hits = schedule(
                stream, 8, batching, lambda members, _cached: _serve_price(p, members)
            )
            rows.append((
                engine, SERVE_RANKS, SERVE_REQUESTS, SERVE_BASE_N, batching,
                nbatches, throughput(outcomes),
                latency_percentile(outcomes, 0.50),
                latency_percentile(outcomes, 0.95),
                latency_max(outcomes),
            ))
    return rows


CACHE_REQUESTS = 64
CACHE_BASE_N = 32


def cache_rows():
    """Factor-cache rows of BENCH_serving.json: each row is
    (engine, ranks, requests, base_n, cache, hits, batches, throughput,
    p95, max).  The 64-request demo stream re-enters the LU (diagdom, 32)
    and Cholesky (spd, 96) operators in later groups; a flagged batch
    prices only its two panel substitutions (Cluster::solve_batch_cached)."""
    stream = demo_stream(CACHE_REQUESTS, CACHE_BASE_N)
    rows = []
    for gpu in (False, True):
        p = params(SERVE_RANKS, gpu)
        engine = "MPI+CUDA" if gpu else "MPI+ATLAS"
        for cache in (True, False):
            def price(members, cached, p=p):
                if cached:
                    return 2.0 * trsm_makespan(members[0]["n"], len(members), p, 4)
                return _serve_price(p, members)
            outcomes, nbatches, hits = schedule(
                stream, 8, True, price, factor_cache=cache
            )
            rows.append((
                engine, SERVE_RANKS, CACHE_REQUESTS, CACHE_BASE_N, cache,
                hits, nbatches, throughput(outcomes),
                latency_percentile(outcomes, 0.95),
                latency_max(outcomes),
            ))
    return rows


HALO_STENCILS = (("poisson2d", 512, 2), ("poisson3d", 64, 3))
HALO_ITERS = 100

GPUDIRECT_ITERS = 100
GPUDIRECT_SUMMA_N = 16_384


def gpudirect_rows():
    """Dense rows of BENCH_gpudirect.json (rust/benches/gpudirect.rs): each
    row is (kernel, engine, n, ranks, pr, pc, wire_stage, staged, gpudirect,
    strict) where staged = prefetch twin + wire stage and `strict` means a
    device-dirty payload hit the wire (stage > 0)."""
    iters = GPUDIRECT_ITERS
    rows = []
    for ranks in PAPER_RANKS:
        for gpu in (False, True):
            p = params(ranks, gpu)
            engine = "MPI+CUDA" if gpu else "MPI+ATLAS"

            def push(kernel, n, stage, prefetch, gpudirect):
                rows.append((
                    kernel, engine, n, ranks, p.pr, p.pc,
                    stage, prefetch + stage, gpudirect, stage > 0.0,
                ))

            push(
                "LU", PAPER_N,
                lu_wire_stage(PAPER_N, p, 4),
                lu_makespan_prefetch(PAPER_N, p, 4),
                lu_makespan_gpudirect(PAPER_N, p, 4),
            )
            push(
                "Cholesky", PAPER_N,
                chol_wire_stage(PAPER_N, p, 4),
                chol_makespan_prefetch(PAPER_N, p, 4),
                chol_makespan_gpudirect(PAPER_N, p, 4),
            )
            push(
                "SUMMA", GPUDIRECT_SUMMA_N,
                summa_wire_stage(GPUDIRECT_SUMMA_N, p, 4),
                summa_makespan_prefetch(GPUDIRECT_SUMMA_N, p, 4, True),
                summa_makespan_gpudirect(GPUDIRECT_SUMMA_N, p, 4, True),
            )
            for m, name in (("cg", "CG"), ("bicgstab", "BiCGSTAB")):
                push(
                    name, PAPER_N,
                    iter_wire_stage(m, PAPER_N, iters, p, 4),
                    iter_makespan_prefetch(m, PAPER_N, iters, 30, p, 4),
                    iter_makespan_gpudirect(m, PAPER_N, iters, 30, p, 4),
                )
    return rows


def gpudirect_sparse_rows():
    """Sparse rows of BENCH_gpudirect.json: each row is (stencil, method,
    grid, n, nnz, ranks, staged, gpudirect) — host-arm operands, host-clean
    ghosts, always an exact wash."""
    iters = GPUDIRECT_ITERS
    rows = []
    for ranks in PAPER_RANKS:
        p = params(ranks, gpu=False)
        for stencil, grid, dim in HALO_STENCILS:
            n = grid**dim
            h = stencil_halo_counts(grid, dim, p.tile, p.pr)
            nnz = h["total_nnz"]
            for m, name in (("cg", "CG"), ("bicgstab", "BiCGSTAB")):
                prefetch = sparse_iter_makespan_prefetch(m, n, nnz, iters, 30, p, 8)
                rows.append((
                    stencil, name, grid, n, nnz, ranks,
                    prefetch + sparse_iter_wire_stage(n, nnz, p, 8),
                    sparse_iter_makespan_gpudirect(m, n, nnz, iters, 30, p, 8),
                ))
    return rows


def halo_rows():
    """Rows of BENCH_halo.json (rust/benches/halo.rs): each row is
    (stencil, method, grid, n, nnz, ranks, pr, neighbors, ghost_elems,
    diag_frac, allgather, halo, strict).  ATLAS arm only — the sparse path
    has no AOT kernels."""
    rows = []
    for ranks in PAPER_RANKS:
        p = params(ranks, gpu=False)
        pr = p.pr
        for stencil, grid, dim in HALO_STENCILS:
            n = grid**dim
            h = stencil_halo_counts(grid, dim, p.tile, pr)
            diag_frac = h["diag_nnz"] / h["total_nnz"]
            for m, name in (("cg", "CG"), ("bicgstab", "BiCGSTAB")):
                rows.append((
                    stencil, name, grid, n, h["total_nnz"], ranks, pr,
                    h["neighbors"], h["ghost_elems"], diag_frac,
                    sparse_iter_makespan_split(
                        m, n, h["total_nnz"], HALO_ITERS, diag_frac, p, 8
                    ),
                    sparse_iter_makespan_halo(
                        m, n, h["total_nnz"], HALO_ITERS, diag_frac,
                        h["neighbors"], h["ghost_elems"], p, 8
                    ),
                    pr > 1,
                ))
    return rows


# ---------------------------------------------------------------------------
# Committed-artifact rendering (byte-identical to the rust benches' output)
# ---------------------------------------------------------------------------


MIXED_ITERS = 100


def mixed_rows():
    """Dense rows of BENCH_mixed.json (rust/benches/mixed.rs): each row is
    (kernel, engine, n, ranks, pr, pc, f64, mixed, strict) where `strict`
    means the dtype x profile gate is open and mixed must win outright."""
    iters = MIXED_ITERS
    rows = []
    for ranks in PAPER_RANKS:
        for gpu in (False, True):
            p = params(ranks, gpu)
            engine = "MPI+CUDA" if gpu else "MPI+ATLAS"
            strict = model_mixed_engaged(p, 8)

            def push(kernel, f64_secs, mixed_secs):
                rows.append((
                    kernel, engine, PAPER_N, ranks, p.pr, p.pc,
                    f64_secs, mixed_secs, strict,
                ))

            push(
                "LU",
                lu_makespan_gpudirect(PAPER_N, p, 8),
                lu_makespan_refined(PAPER_N, p, 8),
            )
            push(
                "Cholesky",
                chol_makespan_gpudirect(PAPER_N, p, 8),
                chol_makespan_refined(PAPER_N, p, 8),
            )
            for m, name in (("cg", "CG"), ("bicgstab", "BiCGSTAB")):
                push(
                    name,
                    iter_makespan_gpudirect(m, PAPER_N, iters, 30, p, 8),
                    iter_makespan_mixed(m, PAPER_N, iters, 30, p, 8),
                )
    return rows


def mixed_sparse_rows():
    """Sparse rows of BENCH_mixed.json: each row is (stencil, method, grid,
    n, nnz, engine, ranks, f64, mixed, strict)."""
    iters = MIXED_ITERS
    rows = []
    for ranks in PAPER_RANKS:
        for gpu in (False, True):
            p = params(ranks, gpu)
            engine = "MPI+CUDA" if gpu else "MPI+ATLAS"
            strict = model_mixed_engaged(p, 8)
            for stencil, grid, dim in HALO_STENCILS:
                n = grid ** dim
                nnz = stencil_halo_counts(grid, dim, p.tile, p.pr)["total_nnz"]
                for m, name in (("cg", "CG"), ("bicgstab", "BiCGSTAB")):
                    rows.append((
                        stencil, name, grid, n, nnz, engine, ranks,
                        sparse_iter_makespan_gpudirect(m, n, nnz, iters, 30, p, 8),
                        sparse_iter_makespan_mixed(m, n, nnz, iters, 30, p, 8),
                        strict,
                    ))
    return rows


def _rust_e6(x):
    """Rust's `{:.6e}`: no '+' sign, no zero-padded exponent."""
    m, e = f"{x:.6e}".split("e")
    return f"{m}e{int(e)}"


def render_overlap_json():
    """The exact bytes `cargo bench --bench overlap` writes."""
    rows = overlap_rows()
    lines = ['{', '  "network": "gigabit_ethernet",', '  "entries": [']
    for i, (kernel, engine, n, ranks, blocking, overlapped) in enumerate(rows):
        comma = "," if i + 1 < len(rows) else ""
        lines.append(
            f'    {{"kernel": "{kernel}", "engine": "{engine}", "n": {n}, '
            f'"ranks": {ranks}, "blocking_secs": {_rust_e6(blocking)}, '
            f'"overlapped_secs": {_rust_e6(overlapped)}, '
            f'"hidden_frac": {1.0 - overlapped / blocking:.4f}}}{comma}'
        )
    return "\n".join(lines + ["  ]", "}", ""])


def render_prefetch_json():
    """The exact bytes `cargo bench --bench prefetch` writes."""
    rows = prefetch_rows()
    lines = ['{', '  "network": "gigabit_ethernet",',
             f'  "device_mem_bytes": {DEFAULT_DEVICE_MEM},', '  "entries": [']
    for i, (kernel, engine, n, ranks, streaming, resident, prefetch, _s) in enumerate(rows):
        comma = "," if i + 1 < len(rows) else ""
        lines.append(
            f'    {{"kernel": "{kernel}", "engine": "{engine}", "n": {n}, '
            f'"ranks": {ranks}, "streaming_secs": {_rust_e6(streaming)}, '
            f'"resident_secs": {_rust_e6(resident)}, '
            f'"prefetch_secs": {_rust_e6(prefetch)}, '
            f'"hidden_frac": {1.0 - prefetch / resident:.4f}}}{comma}'
        )
    return "\n".join(lines + ["  ]", "}", ""])


def render_residency_json():
    """The exact bytes `cargo bench --bench residency` writes."""
    rows = residency_rows()
    lines = ['{', '  "network": "gigabit_ethernet",',
             f'  "device_mem_bytes": {DEFAULT_DEVICE_MEM},', '  "entries": [']
    for i, (kernel, engine, n, ranks, streaming, cached, _strict) in enumerate(rows):
        comma = "," if i + 1 < len(rows) else ""
        lines.append(
            f'    {{"kernel": "{kernel}", "engine": "{engine}", "n": {n}, '
            f'"ranks": {ranks}, "streaming_secs": {_rust_e6(streaming)}, '
            f'"cached_secs": {_rust_e6(cached)}, '
            f'"saved_frac": {1.0 - cached / streaming:.4f}}}{comma}'
        )
    return "\n".join(lines + ["  ]", "}", ""])


def render_halo_json():
    """The exact bytes `cargo bench --bench halo` writes."""
    rows = halo_rows()
    lines = ['{', '  "network": "gigabit_ethernet",', '  "entries": [']
    for i, (stencil, method, grid, n, nnz, ranks, pr, neighbors, ghost,
            diag_frac, ag, ha, _strict) in enumerate(rows):
        comma = "," if i + 1 < len(rows) else ""
        lines.append(
            f'    {{"stencil": "{stencil}", "method": "{method}", '
            f'"grid": {grid}, "n": {n}, "nnz": {nnz}, "ranks": {ranks}, '
            f'"pr": {pr}, "neighbors": {neighbors}, "ghost_elems": {ghost}, '
            f'"diag_frac": {diag_frac:.6f}, '
            f'"allgather_secs": {_rust_e6(ag)}, "halo_secs": {_rust_e6(ha)}, '
            f'"saved_frac": {1.0 - ha / ag:.4f}}}{comma}'
        )
    return "\n".join(lines + ["  ]", "}", ""])


def render_gpudirect_json():
    """The exact bytes `cargo bench --bench gpudirect` writes."""
    rows = gpudirect_rows()
    srows = gpudirect_sparse_rows()
    lines = ['{', '  "network": "gigabit_ethernet",', '  "tile": 256,',
             f'  "iters": {GPUDIRECT_ITERS},', '  "entries": [']
    for i, (kernel, engine, n, ranks, pr, pc, stage, staged,
            gpudirect, strict) in enumerate(rows):
        comma = "," if i + 1 < len(rows) else ""
        flag = "true" if strict else "false"
        lines.append(
            f'    {{"kernel": "{kernel}", "engine": "{engine}", "n": {n}, '
            f'"ranks": {ranks}, "pr": {pr}, "pc": {pc}, '
            f'"wire_stage_secs": {_rust_e6(stage)}, '
            f'"staged_secs": {_rust_e6(staged)}, '
            f'"gpudirect_secs": {_rust_e6(gpudirect)}, '
            f'"saved_frac": {1.0 - gpudirect / staged:.4f}, '
            f'"strict": {flag}}}{comma}'
        )
    lines += ['  ],', '  "sparse": [']
    for i, (stencil, method, grid, n, nnz, ranks, staged,
            gpudirect) in enumerate(srows):
        comma = "," if i + 1 < len(srows) else ""
        lines.append(
            f'    {{"stencil": "{stencil}", "method": "{method}", '
            f'"grid": {grid}, "n": {n}, "nnz": {nnz}, "ranks": {ranks}, '
            f'"staged_secs": {_rust_e6(staged)}, '
            f'"gpudirect_secs": {_rust_e6(gpudirect)}}}{comma}'
        )
    return "\n".join(lines + ["  ]", "}", ""])


def render_serving_json():
    """The exact bytes `cargo bench --bench serving` writes."""
    rows = serving_entries()
    srows = serving_rows()
    lines = ['{', '  "network": "gigabit_ethernet",', '  "tile": 256,',
             f'  "iters": {SERVE_ITERS},', '  "entries": [']
    for i, (kernel, engine, n, ranks, k, single, looped, batched) in enumerate(rows):
        comma = "," if i + 1 < len(rows) else ""
        lines.append(
            f'    {{"kernel": "{kernel}", "engine": "{engine}", "n": {n}, '
            f'"ranks": {ranks}, "k": {k}, "single_secs": {_rust_e6(single)}, '
            f'"looped_secs": {_rust_e6(looped)}, '
            f'"batched_secs": {_rust_e6(batched)}, '
            f'"speedup": {looped / batched:.4f}}}{comma}'
        )
    lines += ['  ],', '  "serving": [']
    for i, (engine, ranks, requests, base_n, batching, batches,
            tput, p50, p95, mx) in enumerate(srows):
        comma = "," if i + 1 < len(srows) else ""
        flag = "true" if batching else "false"
        lines.append(
            f'    {{"engine": "{engine}", "ranks": {ranks}, '
            f'"requests": {requests}, "base_n": {base_n}, '
            f'"batching": {flag}, "batches": {batches}, '
            f'"throughput_rps": {_rust_e6(tput)}, '
            f'"p50_secs": {_rust_e6(p50)}, "p95_secs": {_rust_e6(p95)}, '
            f'"max_secs": {_rust_e6(mx)}}}{comma}'
        )
    crows = cache_rows()
    lines += ['  ],', '  "factor_cache": [']
    for i, (engine, ranks, requests, base_n, cache, hits, batches,
            tput, p95, mx) in enumerate(crows):
        comma = "," if i + 1 < len(crows) else ""
        flag = "true" if cache else "false"
        lines.append(
            f'    {{"engine": "{engine}", "ranks": {ranks}, '
            f'"requests": {requests}, "base_n": {base_n}, '
            f'"cache": {flag}, "hits": {hits}, "batches": {batches}, '
            f'"throughput_rps": {_rust_e6(tput)}, '
            f'"p95_secs": {_rust_e6(p95)}, "max_secs": {_rust_e6(mx)}}}{comma}'
        )
    return "\n".join(lines + ["  ]", "}", ""])


def render_mixed_json():
    """The exact bytes `cargo bench --bench mixed` writes."""
    rows = mixed_rows()
    srows = mixed_sparse_rows()
    lines = ['{', '  "network": "gigabit_ethernet",', '  "tile": 256,',
             f'  "iters": {MIXED_ITERS},',
             f'  "refine_iters": {MODEL_REFINE_ITERS},', '  "entries": [']
    for i, (kernel, engine, n, ranks, pr, pc, wide, mixed,
            strict) in enumerate(rows):
        comma = "," if i + 1 < len(rows) else ""
        flag = "true" if strict else "false"
        lines.append(
            f'    {{"kernel": "{kernel}", "engine": "{engine}", "n": {n}, '
            f'"ranks": {ranks}, "pr": {pr}, "pc": {pc}, '
            f'"f64_secs": {_rust_e6(wide)}, "mixed_secs": {_rust_e6(mixed)}, '
            f'"saved_frac": {1.0 - mixed / wide:.4f}, "strict": {flag}}}{comma}'
        )
    lines += ['  ],', '  "sparse": [']
    for i, (stencil, method, grid, n, nnz, engine, ranks, wide, mixed,
            strict) in enumerate(srows):
        comma = "," if i + 1 < len(srows) else ""
        flag = "true" if strict else "false"
        lines.append(
            f'    {{"stencil": "{stencil}", "method": "{method}", '
            f'"grid": {grid}, "n": {n}, "nnz": {nnz}, "engine": "{engine}", '
            f'"ranks": {ranks}, "f64_secs": {_rust_e6(wide)}, '
            f'"mixed_secs": {_rust_e6(mixed)}, '
            f'"saved_frac": {1.0 - mixed / wide:.4f}, "strict": {flag}}}{comma}'
        )
    return "\n".join(lines + ["  ]", "}", ""])


FAULTS_ITERS = 100
FAULTS_RESTART = 30
FAULTS_EVERY_DIRECT = 16
FAULTS_EVERY_KRYLOV = 10
FAULTS_CRASH_FRACS = (0.25, 0.5, 0.9)
FAULTS_REBOOT = 0.5  # comm/faults.rs FaultPlan::default().reboot_secs


def faults_rows():
    """Rows of BENCH_faults.json (rust/benches/faults.rs): each row is
    (kernel, engine, n, ranks, pr, pc, every, crash, base, ckpt, legs,
    full_rec, ckpt_rec, strict).  Row order mirrors the bench exactly:
    direct kernels interleave LU/Cholesky per crash fraction."""
    rows = []
    for ranks in PAPER_RANKS:
        for gpu in (False, True):
            p = params(ranks, gpu)
            engine = "MPI+CUDA" if gpu else "MPI+ATLAS"

            panels = n_panels(PAPER_N, p)
            dlegs = (
                n_checkpoints(panels, FAULTS_EVERY_DIRECT)
                * ckpt_leg(PAPER_N, p, 4)
            )
            for frac in FAULTS_CRASH_FRACS:
                crash = max(int(panels * frac), FAULTS_EVERY_DIRECT)
                rows.append((
                    "LU", engine, PAPER_N, ranks, p.pr, p.pc,
                    FAULTS_EVERY_DIRECT, crash,
                    lu_makespan_gpudirect(PAPER_N, p, 4),
                    lu_makespan_ckpt(PAPER_N, FAULTS_EVERY_DIRECT, p, 4),
                    dlegs,
                    lu_recovery_full(PAPER_N, crash, FAULTS_REBOOT, p, 4),
                    lu_recovery_ckpt(
                        PAPER_N, FAULTS_EVERY_DIRECT, crash, FAULTS_REBOOT, p, 4
                    ),
                    crash >= FAULTS_EVERY_DIRECT,
                ))
                rows.append((
                    "Cholesky", engine, PAPER_N, ranks, p.pr, p.pc,
                    FAULTS_EVERY_DIRECT, crash,
                    chol_makespan_gpudirect(PAPER_N, p, 4),
                    chol_makespan_ckpt(PAPER_N, FAULTS_EVERY_DIRECT, p, 4),
                    dlegs,
                    chol_recovery_full(PAPER_N, crash, FAULTS_REBOOT, p, 4),
                    chol_recovery_ckpt(
                        PAPER_N, FAULTS_EVERY_DIRECT, crash, FAULTS_REBOOT, p, 4
                    ),
                    crash >= FAULTS_EVERY_DIRECT,
                ))

            for m, name in (("cg", "CG"), ("bicgstab", "BiCGSTAB")):
                period = krylov_snap_period(m, FAULTS_EVERY_KRYLOV, FAULTS_RESTART)
                klegs = (
                    n_checkpoints(FAULTS_ITERS, period)
                    * krylov_snap_leg(m, PAPER_N, p, 4)
                )
                for frac in FAULTS_CRASH_FRACS:
                    crash = max(int(FAULTS_ITERS * frac), period)
                    rows.append((
                        name, engine, PAPER_N, ranks, p.pr, p.pc,
                        period, crash,
                        iter_makespan_gpudirect(
                            m, PAPER_N, FAULTS_ITERS, FAULTS_RESTART, p, 4
                        ),
                        iter_makespan_ckpt(
                            m, PAPER_N, FAULTS_ITERS, FAULTS_RESTART,
                            FAULTS_EVERY_KRYLOV, p, 4,
                        ),
                        klegs,
                        iter_recovery_full(
                            m, PAPER_N, FAULTS_ITERS, FAULTS_RESTART, crash,
                            FAULTS_REBOOT, p, 4,
                        ),
                        iter_recovery_ckpt(
                            m, PAPER_N, FAULTS_ITERS, FAULTS_RESTART,
                            FAULTS_EVERY_KRYLOV, crash, FAULTS_REBOOT, p, 4,
                        ),
                        crash >= period,
                    ))
    return rows


def render_faults_json():
    """The exact bytes `cargo bench --bench faults` writes."""
    rows = faults_rows()
    lines = ['{', '  "network": "gigabit_ethernet",', '  "tile": 256,',
             f'  "n": {PAPER_N},', f'  "iters": {FAULTS_ITERS},',
             f'  "every_direct": {FAULTS_EVERY_DIRECT},',
             f'  "every_krylov": {FAULTS_EVERY_KRYLOV},',
             f'  "reboot_secs": {_rust_e6(FAULTS_REBOOT)},', '  "entries": [']
    for i, (kernel, engine, n, ranks, pr, pc, every, crash, base, ckpt,
            legs, full_rec, ckpt_rec, strict) in enumerate(rows):
        comma = "," if i + 1 < len(rows) else ""
        flag = "true" if strict else "false"
        lines.append(
            f'    {{"kernel": "{kernel}", "engine": "{engine}", "n": {n}, '
            f'"ranks": {ranks}, "pr": {pr}, "pc": {pc}, "every": {every}, '
            f'"crash": {crash}, "base_secs": {_rust_e6(base)}, '
            f'"ckpt_secs": {_rust_e6(ckpt)}, "legs_secs": {_rust_e6(legs)}, '
            f'"full_recovery_secs": {_rust_e6(full_rec)}, '
            f'"ckpt_recovery_secs": {_rust_e6(ckpt_rec)}, '
            f'"saved_frac": {1.0 - ckpt_rec / full_rec:.4f}, '
            f'"strict": {flag}}}{comma}'
        )
    return "\n".join(lines + ["  ]", "}", ""])
