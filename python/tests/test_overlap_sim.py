"""Simulation oracles for the split-phase / overlap refactor (DESIGN.md §11).

The rust side cannot always be executed in CI-less containers, so the
*mathematical* content of the overlap PR is verified here against numpy:

* the two-timeline virtual clock's bounds (max <= overlapped <= sum,
  overlap never loses vs blocking on an identical trace);
* the depth-1 lookahead LU schedule (deferred pivot application, column
  k+1 updated and factored ahead of the trailing update) produces results
  *bit-identical* to the classic right-looking schedule, which itself
  satisfies P A = L U;
* the lookahead Cholesky schedule, likewise bit-identical to classic;
* the split (diagonal-block / off-block) masked spmv composes to the full
  matvec;
* rectangular tiled GEMM with identity edge padding requires the pad mask
  the pipelined SUMMA applies — and is exact with it;
* the pipelined-CG (Ghysels) recurrences solve SPD systems to the same
  tolerance as classic CG.

Pure numpy: runs in the CI `python-oracles` job without jax.
"""

import numpy as np
import pytest

RNG = np.random.default_rng(0xC0FFEE)


# ---------------------------------------------------------------------------
# Two-timeline virtual clock
# ---------------------------------------------------------------------------

class Clock:
    """Mirror of comm::clock::VClock (now + nic_free timelines)."""

    def __init__(self):
        self.now = 0.0
        self.nic_free = 0.0
        self.compute = 0.0
        self.comm_wait = 0.0

    def advance_compute(self, dt):
        self.now += dt
        self.compute += dt

    def nic_occupy(self, dt):
        start = max(self.now, self.nic_free)
        self.nic_free = start + dt
        return self.nic_free

    def observe_arrival(self, arrival):
        if arrival > self.now:
            self.comm_wait += arrival - self.now
            self.now = arrival

    def advance_send(self, dt):  # blocking send
        self.observe_arrival(self.nic_occupy(dt))

    def busy_until(self):
        return max(self.now, self.nic_free)


def test_clock_overlap_bounds_hold_on_random_traces():
    for case in range(300):
        rng = np.random.default_rng(case)
        blocking, overlapped = Clock(), Clock()
        total_compute = total_send = total_comm_blocking = 0.0
        for _ in range(rng.integers(1, 40)):
            kind = rng.integers(0, 3)
            if kind == 0:
                dt = float(rng.uniform(0, 2))
                blocking.advance_compute(dt)
                overlapped.advance_compute(dt)
                total_compute += dt
            elif kind == 1:
                dt = float(rng.uniform(0, 1))
                blocking.advance_send(dt)
                overlapped.nic_occupy(dt)
                total_send += dt
                total_comm_blocking += dt
            else:
                arr = float(rng.uniform(0, 10))
                total_comm_blocking += max(0.0, arr - blocking.now)
                blocking.observe_arrival(arr)
                overlapped.observe_arrival(arr)
        ms_over, ms_block = overlapped.busy_until(), blocking.busy_until()
        eps = 1e-12
        assert max(total_compute, total_send) <= ms_over + eps
        assert ms_over <= total_compute + total_comm_blocking + eps
        assert ms_over <= ms_block + eps
        assert abs(overlapped.compute - total_compute) < 1e-9


# ---------------------------------------------------------------------------
# Tile-level LU schedules (classic vs depth-1 lookahead)
# ---------------------------------------------------------------------------

def _embed_identity(a, t):
    """Pad to a multiple of t with the identity (dist::descriptor::pad)."""
    n = a.shape[0]
    kt = -(-n // t)
    out = np.eye(kt * t, dtype=a.dtype)
    out[:n, :n] = a
    return out, kt


def _factor_panel(a, k, t, n_real_total):
    """getrf with partial pivoting on panel column k (rows k*t..), pivot
    search restricted to the real (unpadded) rows; swaps applied *within the
    panel column only*.  Returns global pivot rows, one per eliminated
    column (mirrors linalg::getrf_lda + the rust factor_panel)."""
    kt = a.shape[0] // t
    top = k * t
    m_real = n_real_total - top          # real rows below the panel top
    n_real = min(m_real, t)              # real panel width
    piv = []
    for col in range(n_real):
        g = top + col
        # pivot search over real rows only
        sub = a[g:n_real_total, top + col]
        p = g + int(np.argmax(np.abs(sub)))
        piv.append(p)
        if p != g:
            a[[g, p], top:top + t] = a[[p, g], top:top + t]  # panel column only
        pivval = a[g, top + col]
        assert abs(pivval) > 1e-300, "singular panel"
        a[g + 1:kt * t, top + col] /= pivval
        a[g + 1:kt * t, top + col + 1:top + t] -= np.outer(
            a[g + 1:kt * t, top + col], a[g, top + col + 1:top + t]
        )
    return piv


def _apply_swaps_outside(a, piv, k, t):
    swaps = []
    top = k * t
    for j, pg in enumerate(piv):
        g1 = top + j
        if g1 != pg:
            swaps.append((g1, pg))
            cols = np.r_[0:top, top + t:a.shape[1]]
            a[np.ix_([g1, pg], cols)] = a[np.ix_([pg, g1], cols)]
    return swaps


def _u12_row(a, k, t, kt):
    top = k * t
    l11 = np.tril(a[top:top + t, top:top + t], -1) + np.eye(t)
    for j in range(k + 1, kt):
        a[top:top + t, j * t:(j + 1) * t] = np.linalg.solve(
            l11, a[top:top + t, j * t:(j + 1) * t]
        )


def _tile_update(a, i, k, j, t):
    a[i * t:(i + 1) * t, j * t:(j + 1) * t] -= (
        a[i * t:(i + 1) * t, k * t:(k + 1) * t]
        @ a[k * t:(k + 1) * t, j * t:(j + 1) * t]
    )


def lu_classic(a0, t, n_real):
    a = a0.copy()
    kt = a.shape[0] // t
    swaps = []
    for k in range(kt):
        piv = _factor_panel(a, k, t, n_real)
        swaps += _apply_swaps_outside(a, piv, k, t)
        if k + 1 == kt:
            break
        _u12_row(a, k, t, kt)
        for i in range(k + 1, kt):
            for j in range(k + 1, kt):
                _tile_update(a, i, k, j, t)
    return a, swaps


def lu_lookahead(a0, t, n_real):
    """Mirror of the new solvers/direct/lu.rs schedule."""
    a = a0.copy()
    kt = a.shape[0] // t
    swaps = []
    piv_pending = _factor_panel(a, 0, t, n_real)
    for k in range(kt):
        piv = piv_pending
        swaps += _apply_swaps_outside(a, piv, k, t)
        if k + 1 == kt:
            break
        _u12_row(a, k, t, kt)
        # lookahead: tile column k+1 first, then factor it early
        for i in range(k + 1, kt):
            _tile_update(a, i, k, k + 1, t)
        piv_pending = _factor_panel(a, k + 1, t, n_real)
        # trailing update for the remaining columns
        for i in range(k + 1, kt):
            for j in range(k + 2, kt):
                _tile_update(a, i, k, j, t)
    return a, swaps


@pytest.mark.parametrize("n,t", [(16, 4), (24, 8), (13, 4), (21, 8), (7, 8)])
def test_lookahead_lu_bit_identical_to_classic_and_correct(n, t):
    a0 = RNG.standard_normal((n, n))
    ap, kt = _embed_identity(a0, t)
    classic, swaps_c = lu_classic(ap, t, n)
    look, swaps_l = lu_lookahead(ap, t, n)
    # The lookahead schedule reorders whole-tile ops but every element sees
    # the identical op sequence: results must match bit for bit.
    assert swaps_c == swaps_l
    assert np.array_equal(classic, look)
    # And the classic schedule is a genuine LU: P A = L U on the real block.
    pa = ap.copy()
    for g1, g2 in swaps_c:
        pa[[g1, g2], :] = pa[[g2, g1], :]
    # swaps inside the panel columns were applied during factorisation; the
    # full permutation applied to A0 is the ordered swap list
    nn = ap.shape[0]
    l = np.tril(look, -1) + np.eye(nn)
    u = np.triu(look)
    assert np.allclose(l @ u, pa, atol=1e-10), np.abs(l @ u - pa).max()
    # identity padding is preserved exactly
    assert np.array_equal(look[n:, n:], np.eye(nn - n))


# ---------------------------------------------------------------------------
# Tile-level Cholesky schedules
# ---------------------------------------------------------------------------

def _chol_panel(a, k, t, kt):
    top = k * t
    a[top:top + t, top:top + t] = np.linalg.cholesky(a[top:top + t, top:top + t])
    l11 = a[top:top + t, top:top + t]
    for i in range(k + 1, kt):
        # solve L(i,k) L11^T = A(i,k)
        a[i * t:(i + 1) * t, top:top + t] = np.linalg.solve(
            l11, a[i * t:(i + 1) * t, top:top + t].T
        ).T


def _chol_tile_update(a, i, k, j, t):
    a[i * t:(i + 1) * t, j * t:(j + 1) * t] -= (
        a[i * t:(i + 1) * t, k * t:(k + 1) * t]
        @ a[j * t:(j + 1) * t, k * t:(k + 1) * t].T
    )


def chol_classic(a0, t):
    a = a0.copy()
    kt = a.shape[0] // t
    for k in range(kt):
        _chol_panel(a, k, t, kt)
        for i in range(k + 1, kt):
            for j in range(k + 1, i + 1):
                _chol_tile_update(a, i, k, j, t)
    return a


def chol_lookahead(a0, t):
    """Mirror of the new solvers/direct/cholesky.rs schedule."""
    a = a0.copy()
    kt = a.shape[0] // t
    _chol_panel(a, 0, t, kt)
    for k in range(kt):
        if k + 1 == kt:
            break
        # lookahead: column k+1 first, factor it early
        for i in range(k + 1, kt):
            _chol_tile_update(a, i, k, k + 1, t)
        _chol_panel(a, k + 1, t, kt)
        # remaining lower-half trailing columns
        for i in range(k + 1, kt):
            for j in range(k + 2, i + 1):
                _chol_tile_update(a, i, k, j, t)
    return a


@pytest.mark.parametrize("n,t", [(16, 4), (24, 8), (12, 4)])
def test_lookahead_cholesky_bit_identical_to_classic_and_correct(n, t):
    m = RNG.standard_normal((n, n))
    a0 = m @ m.T + n * np.eye(n)
    classic = chol_classic(a0, t)
    look = chol_lookahead(a0, t)
    assert np.array_equal(np.tril(classic), np.tril(look))
    l = np.tril(look)
    assert np.allclose(l @ l.T, a0, atol=1e-9)


# ---------------------------------------------------------------------------
# Split (masked) spmv
# ---------------------------------------------------------------------------

def test_masked_spmv_composes_to_full_matvec():
    n, t, pr = 64, 4, 2
    density = 0.15
    a = RNG.standard_normal((n, n)) * (RNG.random((n, n)) < density)
    x = RNG.standard_normal(n)
    kt = n // t
    for prow in range(pr):
        owned = np.zeros(n, dtype=bool)
        for ti in range(kt):
            if ti % pr == prow:
                owned[ti * t:(ti + 1) * t] = True
        # pass 1 reads only owned columns (remote x may be garbage)
        x_garbage = np.where(owned, x, np.nan)
        y = (a[:, owned] @ x_garbage[owned])
        y += a[:, ~owned] @ x[~owned]
        assert np.allclose(y, a @ x, atol=1e-12)


# ---------------------------------------------------------------------------
# Rectangular tiled GEMM with identity padding: the pad mask is required
# ---------------------------------------------------------------------------

def _pad_identity_rect(a, t):
    m, n = a.shape
    mt, nt = -(-m // t), -(-n // t)
    out = np.zeros((mt * t, nt * t), dtype=a.dtype)
    for i in range(mt * t):
        for j in range(nt * t):
            if i < m and j < n:
                out[i, j] = a[i, j]
            elif i == j:
                out[i, j] = 1.0  # identity pad diagonal
    return out


def test_rectangular_padded_gemm_needs_the_mask():
    m, k, n, t = 10, 6, 14, 4
    a = RNG.standard_normal((m, k))
    b = RNG.standard_normal((k, n))
    ap, bp = _pad_identity_rect(a, t), _pad_identity_rect(b, t)
    want = a @ b
    # Unmasked: the pad-diagonal of A's columns 6..8 hits the pad-diagonal
    # of B's rows 6..8 and corrupts C's real diagonal at (6,6), (7,7).
    c_raw = (ap @ bp)[:m, :n]
    wrong = np.abs(c_raw - want)
    assert wrong[6, 6] > 0.5 and wrong[7, 7] > 0.5, "expected pad pollution"
    # Masked (what pgemm_acc broadcasts): pads zeroed -> exact.
    am, bm = ap.copy(), bp.copy()
    am[m:, :] = 0.0
    am[:, k:] = 0.0
    bm[k:, :] = 0.0
    bm[:, n:] = 0.0
    c_masked = (am @ bm)[:m, :n]
    assert np.allclose(c_masked, want, atol=1e-12)


# ---------------------------------------------------------------------------
# Pipelined CG (Ghysels recurrences)
# ---------------------------------------------------------------------------

def pipecg(a, b, tol=1e-10, max_iter=500):
    n = len(b)
    x = np.zeros(n)
    r = b.copy()
    w = a @ r
    z = s = p = None
    gamma_prev = alpha_prev = None
    bnorm = np.linalg.norm(b)
    for it in range(max_iter):
        gamma = r @ r
        delta = w @ r
        q = a @ w  # overlapped with the (gamma, delta) reduction
        if np.sqrt(gamma) <= tol * bnorm:
            return x, it, True
        if it == 0:
            alpha, beta = gamma / delta, 0.0
            z, s, p = q.copy(), w.copy(), r.copy()
        else:
            beta = gamma / gamma_prev
            denom = delta - beta * gamma / alpha_prev
            assert denom > 0, "pipelined breakdown"
            alpha = gamma / denom
            z = q + beta * z
            s = w + beta * s
            p = r + beta * p
        x += alpha * p
        r -= alpha * s
        w -= alpha * z
        gamma_prev, alpha_prev = gamma, alpha
    return x, max_iter, False


def cg_classic(a, b, tol=1e-10, max_iter=500):
    x = np.zeros(len(b))
    r = b.copy()
    p = r.copy()
    rr = r @ r
    bnorm = np.linalg.norm(b)
    for it in range(max_iter):
        ap = a @ p
        alpha = rr / (p @ ap)
        x += alpha * p
        r -= alpha * ap
        rr_new = r @ r
        if np.sqrt(rr_new) <= tol * bnorm:
            return x, it + 1, True
        p = r + (rr_new / rr) * p
        rr = rr_new
    return x, max_iter, False


def _poisson1d(n):
    a = np.zeros((n, n))
    for i in range(n):
        a[i, i] = 2.0
        if i > 0:
            a[i, i - 1] = -1.0
        if i + 1 < n:
            a[i, i + 1] = -1.0
    return a


@pytest.mark.parametrize("n", [32, 100])
def test_pipecg_matches_cg_solution_and_iteration_scale(n):
    a = _poisson1d(n)
    xt = RNG.standard_normal(n)
    b = a @ xt
    x_pipe, it_pipe, conv_pipe = pipecg(a, b, tol=1e-12, max_iter=5 * n)
    x_cg, it_cg, conv_cg = cg_classic(a, b, tol=1e-12, max_iter=5 * n)
    assert conv_pipe and conv_cg
    assert np.allclose(x_pipe, xt, atol=1e-6)
    assert np.allclose(x_cg, xt, atol=1e-6)
    # Same Krylov method: iteration counts agree up to round-off drift.
    assert abs(it_pipe - it_cg) <= max(3, n // 10)


def test_pipecg_spd_random_matrix():
    n = 60
    m = RNG.standard_normal((n, n))
    a = m @ m.T + n * np.eye(n)
    xt = RNG.standard_normal(n)
    b = a @ xt
    x, _, conv = pipecg(a, b, tol=1e-12, max_iter=10 * n)
    assert conv
    assert np.allclose(x, xt, atol=1e-7)


# ---------------------------------------------------------------------------
# Overlap model invariants (max-form vs sum-form)
# ---------------------------------------------------------------------------

def test_overlapped_schedule_model_never_loses():
    for case in range(200):
        rng = np.random.default_rng(1000 + case)
        panel = rng.uniform(0, 1, 12)
        pre = rng.uniform(0, 1, 12)
        update = rng.uniform(0, 2, 12)
        blocking = float(np.sum(panel + pre + update))
        look = panel[0] + float(
            np.sum(pre) + sum(max(u, p) for u, p in zip(update, list(panel[1:]) + [0.0]))
        )
        assert look <= blocking + 1e-12
        if np.all(panel[1:] > 0) and np.all(update[:-1] > 0):
            assert look < blocking
