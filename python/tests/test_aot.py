"""AOT pipeline tests: HLO text round-trip, manifest format, determinism."""

import os
import tempfile

import pytest
import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    n = aot.build_all(out, tiles=(128,), dtypes=("f32",), verbose=False)
    return out, n


def test_build_count(built):
    out, n = built
    assert n == len(model.OPS)
    files = [f for f in os.listdir(out) if f.endswith(".hlo.txt")]
    assert len(files) == n
    assert os.path.exists(os.path.join(out, "manifest.txt"))


def test_hlo_text_is_parseable_hlo(built):
    out, _ = built
    for f in os.listdir(out):
        if not f.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(out, f)).read()
        assert "HloModule" in text, f
        assert "ENTRY" in text, f
        # return_tuple=True => root is a tuple
        assert "tuple(" in text or "tuple<" in text.lower() or ")" in text


def test_manifest_lines_match_ops(built):
    out, _ = built
    lines = open(os.path.join(out, "manifest.txt")).read().strip().splitlines()
    assert len(lines) == len(model.OPS)
    names = set()
    for line in lines:
        parts = line.split()
        assert len(parts) == 8, line
        art, op, dtype, tile, flops, arity, ins, outs = parts
        assert op in model.OPS
        assert dtype == "f32" and tile == "128"
        assert int(flops) == model.OPS[op][2](128)
        assert int(arity) == len(model.OPS[op][1])
        assert len(ins.split(",")) == int(arity)
        names.add(op)
    assert names == set(model.OPS)


def test_deterministic_lowering():
    """Two lowerings of the same op must produce identical HLO text."""
    t1 = aot.to_hlo_text(model.lower("gemm", 128, "f32"))
    t2 = aot.to_hlo_text(model.lower("gemm", 128, "f32"))
    assert t1 == t2


def test_hlo_executes_on_cpu_pjrt(built):
    """Round-trip sanity: compile the lowered gemm via jax and compare to ref.

    (The rust-side PJRT load of the same text is covered by cargo test
    integration_runtime; here we check the lowered computation itself is
    numerically the gemm we think it is.)
    """
    lowered = model.lower("gemm", 128, "f32")
    compiled = lowered.compile()
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    (got,) = compiled(a, b)
    np.testing.assert_allclose(got, a @ b, rtol=2e-4, atol=2e-4)


def test_shape_str():
    assert aot._shape_str(()) == "s"
    assert aot._shape_str((128,)) == "128"
    assert aot._shape_str((128, 256)) == "128x256"
