"""No-toolchain verification of the batched multi-RHS + serving PR (rust
DESIGN.md §14).

Four independent oracles:

1. **Model-twin inequalities** — exactly what `cargo bench --bench
   serving` asserts: on *every* emitted configuration, `batched == single`
   bit for bit at k = 1 (the batched paths ARE the single-RHS paths) and
   `batched < k x single` strictly for k > 1 (launches, tile broadcasts
   and message latencies are paid per panel step, not per vector) — plus
   off-bench sweeps (odd meshes, both dtypes, non-bench k, tiny n).
2. **Panel-op pricing** — the accel-layer contract the twins ride on:
   a one-column panel prices identically to the single tile op for every
   op and both engine profiles, wider panels strictly beat k looped calls,
   and the cost is monotone in k.
3. **Scheduler arithmetic** — a mirror of `serve/mod.rs` (demo stream,
   FIFO consecutive-compatible batching, the virtual timeline, nearest-rank
   percentiles) replaying the rust unit tests' exact expectations, plus
   the serving-scenario A/B: batching must raise throughput and never
   worsen the tail on the backlogged demo stream.
4. **Factor cache** — the cross-request seen-set over (workload, n,
   method): the 64-request demo stream re-enters exactly two direct
   operators, the cache-off arm never flags a hit, grouping is unchanged,
   and the cached pricing raises throughput without worsening the tail
   (PR 9's serve-layer satellite).
5. **Committed artifact** — `BENCH_serving.json` must be byte-identical
   to what the mirror renders.
"""

import pathlib

import pytest

import model_mirror as mm

LE_SLACK = 1.0 + 1e-9


# ---------------------------------------------------------------------------
# 1. model twins — the bench acceptance shape
# ---------------------------------------------------------------------------


def test_serving_bench_acceptance_shape():
    rows = mm.serving_entries()
    # 5 rank counts x 2 engines x 4 widths x 4 kernels.
    assert len(rows) == len(mm.PAPER_RANKS) * 2 * 4 * 4
    for kernel, engine, n, ranks, k, single, looped, batched in rows:
        assert looped == k * single
        if k == 1:
            assert batched == single, (
                f"{kernel} {engine} P={ranks}: k=1 must be the single-RHS "
                f"path bit for bit"
            )
        else:
            assert batched < looped, (
                f"{kernel} {engine} P={ranks} k={k}: batched {batched} must "
                f"beat {looped} looped singles"
            )


def test_k_1_twins_are_the_single_rhs_twins_bitwise():
    # The bench's assert_eq! pair plus the LU/Cholesky k=1 identities: the
    # batched twins at one column must reproduce the PR-3/PR-4 singles
    # exactly (same terms, same association), not approximately.
    for ranks in mm.PAPER_RANKS:
        for gpu in (False, True):
            for b in (4, 8):
                p = mm.params(ranks, gpu)
                assert mm.trsm_makespan(mm.PAPER_N, 1, p, b) == (
                    mm.trsv_makespan(mm.PAPER_N, p, b)
                ), (ranks, gpu, b)
                assert mm.lu_solve_makespan_batched(mm.PAPER_N, 1, p, b) == (
                    mm.lu_makespan(mm.PAPER_N, p, b)
                ), (ranks, gpu, b)
                assert mm.chol_solve_makespan_batched(mm.PAPER_N, 1, p, b) == (
                    mm.chol_makespan(mm.PAPER_N, p, b)
                ), (ranks, gpu, b)
                assert mm.cg_makespan_batched(mm.PAPER_N, 1, 100, p, b) == (
                    mm.iter_makespan("cg", mm.PAPER_N, 100, 30, p, b)
                ), (ranks, gpu, b)


def test_twins_hold_beyond_bench_configs():
    # Non-bench meshes (incl. non-square), both dtypes, widths the bench
    # never sweeps, small n: the amortization must be structural, not
    # tuned to the emitted grid.  Batched cost must also be monotone in k
    # (more columns never cost less).
    for ranks in (1, 2, 3, 6, 8, 12):
        for gpu in (False, True):
            for b in (4, 8):
                for n in (256, 1_024, 8_192):
                    p = mm.params(ranks, gpu)
                    prev = {"trsm": 0.0, "lu": 0.0, "chol": 0.0, "cg": 0.0}
                    for k in (1, 2, 3, 5, 16):
                        cur = {
                            "trsm": mm.trsm_makespan(n, k, p, b),
                            "lu": mm.lu_solve_makespan_batched(n, k, p, b),
                            "chol": mm.chol_solve_makespan_batched(n, k, p, b),
                            "cg": mm.cg_makespan_batched(n, k, 17, p, b),
                        }
                        singles = {
                            "trsm": mm.trsv_makespan(n, p, b),
                            "lu": mm.lu_makespan(n, p, b),
                            "chol": mm.chol_makespan(n, p, b),
                            "cg": mm.iter_makespan("cg", n, 17, 30, p, b),
                        }
                        for key in cur:
                            if k == 1:
                                assert cur[key] == singles[key], (
                                    ranks, gpu, b, n, key
                                )
                            else:
                                assert cur[key] < k * singles[key], (
                                    ranks, gpu, b, n, k, key
                                )
                            assert cur[key] > prev[key], (ranks, gpu, b, n, k, key)
                        prev = cur


# ---------------------------------------------------------------------------
# 2. panel-op pricing (accel/engine.rs panel_op_cost)
# ---------------------------------------------------------------------------

PANEL_OPS = ("trsv_lu", "trsv_l", "trsv_u", "trsv_lt", "gemv_update",
             "gemv_acc", "gemv", "gemv_t")


def test_one_column_panel_prices_as_the_single_tile_op():
    for profile in (mm.q6600_atlas(), mm.gtx280_cublas()):
        for op in PANEL_OPS:
            for b in (4, 8):
                assert mm.panel_op_cost_total(profile, op, 256, 1, b) == (
                    mm.tile_op_cost_total(profile, op, 256, b)
                ), (profile.name, op, b)


def test_wider_panels_strictly_beat_looped_singles_and_are_monotone():
    # One launch + the tile operand streamed once: strictly below k looped
    # calls for every k > 1, on both profiles (both charge launches), and
    # monotone in k.
    for profile in (mm.q6600_atlas(), mm.gtx280_cublas()):
        for op in PANEL_OPS:
            single = mm.tile_op_cost_total(profile, op, 256, 4)
            prev = 0.0
            for k in (1, 2, 4, 8, 32):
                c = mm.panel_op_cost_total(profile, op, 256, k, 4)
                if k > 1:
                    assert c < k * single, (profile.name, op, k)
                assert c > prev, (profile.name, op, k)
                prev = c


def test_panel_flops_are_exactly_k_times_the_column_flops():
    # Bit-identity contract: batching changes cost, never arithmetic.
    for op in PANEL_OPS:
        for k in (1, 2, 7):
            assert mm.panel_op_flops(op, 256, k) == k * mm.op_flops(op, 256)
            ins, out = mm.panel_operand_elems(op, 256, k)
            sins, sout = mm.op_operand_elems(op, 256)
            # The tile operand appears once; vector operands scale by k.
            assert out == (sout if sout == 256 * 256 else sout * k)
            assert len(ins) == len(sins)


# ---------------------------------------------------------------------------
# 3. scheduler arithmetic (serve/mod.rs mirror)
# ---------------------------------------------------------------------------


def test_demo_stream_is_deterministic_and_mixed():
    s = mm.demo_stream(16, 64)
    assert len(s) == 16
    assert mm._compatible(s[0], s[3])
    assert [s[i]["method"] for i in (0, 4, 8, 12)] == [
        "lu", "cg", "chol", "bicgstab"
    ]
    assert not mm._compatible(s[3], s[4])
    assert s[4]["workload"] == "spd" and s[0]["workload"] == "diagdom"
    assert s[1]["arrival"] > s[0]["arrival"]
    assert s[0]["tol"] != s[1]["tol"]
    assert s == mm.demo_stream(16, 64)


def test_batches_merge_only_consecutive_compatible_requests():
    s = mm.demo_stream(9, 64)
    assert mm.form_batches(s) == [[0, 1, 2, 3], [4, 5, 6, 7], [8]]
    b2 = mm.form_batches(s, rhs_batch=3)
    assert b2[0] == [0, 1, 2] and b2[1] == [3]
    b1 = mm.form_batches(s, batching=False)
    assert len(b1) == 9 and all(len(g) == 1 for g in b1)
    # The batching is a partition: every request exactly once, in order.
    flat = [i for g in mm.form_batches(mm.demo_stream(23, 64)) for i in g]
    assert flat == list(range(23))


def test_schedule_timeline_and_percentiles():
    # The rust unit test's exact numbers: every batch priced at 1 s.
    s = mm.demo_stream(8, 64)
    outcomes, nbatches, hits = mm.schedule(s, 8, True, lambda members, _c: 1.0)
    assert nbatches == 2
    assert hits == 0  # factor_cache defaults off
    arrival0, finish0 = outcomes[0]
    assert finish0 == 0.006 + 1.0  # batch 0 waits for request 3
    arrival4, finish4 = outcomes[4]
    assert finish4 == 1.006 + 1.0  # batch 1 queued behind batch 0
    assert abs((finish4 - arrival4) - (2.006 - 0.008)) < 1e-12
    assert mm.latency_max(outcomes) == finish4 - arrival4
    assert mm.latency_percentile(outcomes, 1.0) == mm.latency_max(outcomes)
    assert (
        mm.latency_percentile(outcomes, 0.50)
        <= mm.latency_percentile(outcomes, 0.95)
        <= mm.latency_max(outcomes)
    )
    assert abs(mm.throughput(outcomes) - 8.0 / 2.006) < 1e-9
    assert mm.throughput([]) == 0.0
    assert mm.latency_percentile([], 0.5) == 0.0


def test_serving_scenario_batching_never_loses():
    # The bench's serving A/B on the real pricing: 4 rows (two engines x
    # on/off); on the backlogged demo stream batching must raise
    # throughput strictly and never worsen the worst latency.
    rows = mm.serving_rows()
    assert len(rows) == 4
    for on, off in (rows[0:2], rows[2:4]):
        assert on[4] is True and off[4] is False  # batching flag
        assert on[0] == off[0]  # same engine arm
        assert on[5] == 4 and off[5] == 16  # groups of four vs singletons
        assert on[6] > off[6], f"{on[0]}: batching must raise throughput"
        assert on[9] <= off[9] * LE_SLACK, f"{on[0]}: tail must not worsen"
        assert on[7] <= on[8] <= on[9]  # p50 <= p95 <= max


def test_rhs_coeff_is_exact_and_stream_is_arrival_ordered():
    s = mm.demo_stream(32, 100)
    assert all(a["arrival"] <= b["arrival"] for a, b in zip(s, s[1:]))
    # rhs_coeff mirrors rust SolveRequest::rhs_coeff: 1 + (id%8)/8, exact
    # in binary floating point.
    for r in s:
        coeff = 1.0 + 0.125 * (r["id"] % 8)
        assert coeff == 1.0 + (r["id"] % 8) / 8.0


# ---------------------------------------------------------------------------
# 4. the cross-request factor cache (serve/mod.rs seen-set)
# ---------------------------------------------------------------------------


def test_factor_cache_hits_exactly_the_repeated_direct_operators():
    # The 64-request demo stream cycles 16 groups over methods x 3 sizes:
    # the LU (diagdom, 32) group recurs at group 12 and Cholesky (spd, 96)
    # at group 14 — exactly two flagged batches, none on Krylov repeats.
    s = mm.demo_stream(64, 32)
    _, nbatches, hits = mm.schedule(
        s, 8, True, lambda members, _c: 1.0, factor_cache=True
    )
    assert nbatches == 16
    assert hits == 2
    # Cache off: same grouping, never a hit.
    _, nb_off, hits_off = mm.schedule(s, 8, True, lambda members, _c: 1.0)
    assert (nb_off, hits_off) == (16, 0)
    # The short 16-request stream never revisits an operator.
    _, _, hits16 = mm.schedule(
        mm.demo_stream(16, 32), 8, True, lambda members, _c: 1.0,
        factor_cache=True,
    )
    assert hits16 == 0


def test_cached_batches_receive_the_cached_flag_in_arrival_order():
    s = mm.demo_stream(64, 32)
    flagged = []

    def price(members, cached):
        if cached:
            flagged.append((members[0]["method"], members[0]["n"]))
        return 1.0

    mm.schedule(s, 8, True, price, factor_cache=True)
    assert flagged == [("lu", 32), ("chol", 96)]


def test_factor_cache_scenario_never_loses():
    # The bench's cache A/B on the real pricing: 4 rows (two engines x
    # on/off); the cache changes pricing, not grouping, and must raise
    # throughput without worsening the tail.
    rows = mm.cache_rows()
    assert len(rows) == 4
    for on, off in (rows[0:2], rows[2:4]):
        assert on[4] is True and off[4] is False  # cache flag
        assert on[0] == off[0]  # same engine arm
        assert on[5] == 2, f"{on[0]}: the demo stream repeats exactly twice"
        assert off[5] == 0, f"{off[0]}: the cache-off arm must never hit"
        assert on[6] == off[6], "the cache changes pricing, not grouping"
        assert on[7] > off[7], f"{on[0]}: the cache must raise throughput"
        assert on[9] <= off[9] * LE_SLACK, f"{on[0]}: tail must not worsen"


def test_cached_price_is_the_two_resident_substitutions():
    # A flagged batch prices 2·trsm(n, k) — matching
    # Cluster::solve_batch_cached: both substitutions of the resident
    # factors, no factorization, no transpose redistribution.
    p = mm.params(mm.SERVE_RANKS, gpu=True)
    full = mm.lu_solve_makespan_batched(96, 4, p, 4)
    cached = 2.0 * mm.trsm_makespan(96, 4, p, 4)
    assert cached < full


# ---------------------------------------------------------------------------
# 5. committed artifact
# ---------------------------------------------------------------------------


def test_committed_serving_artifact_matches_the_mirror():
    root = pathlib.Path(__file__).resolve().parents[2]
    assert (root / "BENCH_serving.json").read_text() == mm.render_serving_json()


def test_serving_artifact_factor_cache_schema():
    import json

    root = pathlib.Path(__file__).resolve().parents[2]
    doc = json.loads((root / "BENCH_serving.json").read_text())
    cache = doc["factor_cache"]
    assert len(cache) == 4
    for e in cache:
        assert e["requests"] == 64 and e["base_n"] == 32
        assert e["hits"] == (2 if e["cache"] else 0)
        assert e["batches"] == 16
