"""No-toolchain verification of the residency PR (rust DESIGN.md §12).

Four independent oracles:

1. **Model-twin inequalities** — `model_mirror` transcribes
   `bench_harness/model.rs` term by term; here we assert exactly what
   `cargo bench --bench residency` and `--bench overlap` assert, over every
   configuration both benches emit (plus extra shapes), so the committed
   `BENCH_*.json` artifacts are backed by a machine check.
2. **TileCache accounting** — a transcription of `accel/residency.rs`
   replayed on random traces: per-call charges never exceed the paper's
   streaming flow, LRU respects the budget (and the inclusion property:
   a bigger cache never charges more), host mutation invalidates, and the
   pay-up-front write-back charges once per dirty period.
3. **Fused BLAS-1 bit-identity** — the fused kernels `xpay`,
   `axpy_norm2`, `norm2_dot` are the unfused sequences bit for bit
   (float64 *and* float32), including through a whole CG solve.
4. **Branch-free 4-wide GEMM micro-kernel** — a transcription of the new
   `linalg/blas3.rs::gemm_block` inner loop against numpy, including
   zero-heavy operands (the removed skip branch) and remainder columns.
"""

import numpy as np
import pytest

import model_mirror as mm

# ---------------------------------------------------------------------------
# 1. model twins — the bench acceptance shapes
# ---------------------------------------------------------------------------

LE_SLACK = 1.0 + 1e-9


def test_residency_bench_acceptance_shape():
    rows = mm.residency_rows()
    assert len(rows) == len(mm.PAPER_RANKS) * (2 * 6 + 2)
    for kernel, engine, n, ranks, streaming, cached, strict in rows:
        assert cached <= streaming * LE_SLACK, (
            f"{kernel} {engine} P={ranks}: cached {cached} > streaming {streaming}"
        )
        if strict:
            assert cached < streaming, (
                f"{kernel} {engine} P={ranks}: residency/fusion must strictly win"
            )
        else:
            # Host-arm LU/Cholesky: nothing streams either way — exact wash.
            assert cached == pytest.approx(streaming, rel=1e-12), (
                f"{kernel} {engine} P={ranks}: host arm must be a wash"
            )


def test_committed_bench_artifacts_match_the_mirror():
    # The repo-root BENCH_*.json are the perf trajectory the harness reads;
    # they must stay exactly what the model (rust bench or this mirror)
    # produces — a stale or hand-edited artifact fails here.
    import pathlib

    root = pathlib.Path(__file__).resolve().parents[2]
    assert (root / "BENCH_residency.json").read_text() == mm.render_residency_json()
    assert (root / "BENCH_overlap.json").read_text() == mm.render_overlap_json()


def test_overlap_bench_acceptance_shape():
    # The regenerated BENCH_overlap.json baseline must still satisfy the
    # PR-3 asserts (overlap.rs): overlapped <= blocking, strict for LU at
    # P>1 and pipelined CG at pr>1.
    for kernel, engine, n, ranks, blocking, overlapped in mm.overlap_rows():
        assert overlapped <= blocking * LE_SLACK, f"{kernel} {engine} P={ranks}"
        if kernel == "LU" and ranks > 1:
            assert overlapped < blocking, f"LU {engine} P={ranks} must be strict"
        if kernel == "pipelined CG" and mm.near_square(ranks)[0] > 1:
            assert overlapped < blocking, f"pipecg P={ranks} must be strict"


def test_twins_hold_beyond_bench_configs():
    # Sweep shapes/sizes/dtypes the bench doesn't cover, incl. tiny n and
    # non-square meshes: the <= invariant must be structural, not tuned.
    for ranks in (1, 2, 3, 6, 8, 12, 16):
        for gpu in (False, True):
            for b in (4, 8):
                for n in (256, 512, 4_096, 30_000):
                    p = mm.params(ranks, gpu)
                    assert mm.lu_makespan_resident(n, p, b) <= (
                        mm.lu_makespan_lookahead(n, p, b) * LE_SLACK
                    ), (ranks, gpu, b, n)
                    assert mm.chol_makespan_resident(n, p, b) <= (
                        mm.chol_makespan(n, p, b) * LE_SLACK
                    ), (ranks, gpu, b, n)
                    for ov in (False, True):
                        assert mm.summa_makespan_resident(n, p, b, ov) <= (
                            mm.summa_makespan(n, p, b, ov) * LE_SLACK
                        ), (ranks, gpu, b, n, ov)
                    for m in ("cg", "pipecg", "bicgstab"):
                        for iters in (0, 1, 37):
                            assert mm.iter_makespan_fused(m, n, iters, 30, p, b) <= (
                                mm.iter_makespan(m, n, iters, 30, p, b) * LE_SLACK
                            ), (ranks, gpu, b, n, m, iters)


def test_device_budget_gates_dense_matvec_residency():
    # n=60000 f32: a rank's tile share fits the 1 GiB budget only at P=16.
    n = mm.PAPER_N
    for ranks, fits in ((1, False), (4, False), (16, True)):
        p = mm.params(ranks, True)
        kt = mm.ceil_div(n, p.tile)
        tiles = mm.ceil_div(kt, p.pr) * mm.ceil_div(kt, p.pc)
        assert (tiles * p.tile * p.tile * 4 <= p.device_mem) == fits


def test_fused_solvers_do_not_add_reduction_latency():
    # Pure-latency regime (tiny vectors, big mesh): the fused BiCGSTAB
    # must still win — it trades six reduction waits for four.
    p = mm.params(16, False)
    n = 1_024
    s = mm.iter_makespan("bicgstab", n, 100, 30, p, 8)
    c = mm.iter_makespan_fused("bicgstab", n, 100, 30, p, 8)
    assert c < s


# ---------------------------------------------------------------------------
# 2. TileCache transcription + properties
# ---------------------------------------------------------------------------


class TileCache:
    """Transcription of accel/residency.rs::TileCache."""

    def __init__(self, budget):
        self.budget = budget
        self.map = {}  # key -> [bytes, dirty, tick]
        self.used = 0
        self.tick = 0

    def _next_tick(self):
        self.tick += 1
        return self.tick

    def _make_room(self, extra):
        while self.used + extra > self.budget and self.map:
            victim = min(self.map, key=lambda k: self.map[k][2])
            self.used -= self.map.pop(victim)[0]

    def _touch_read(self, key, nbytes):
        tick = self._next_tick()
        if key in self.map:
            self.map[key][2] = tick
            return 0
        if nbytes > self.budget:
            return nbytes
        self._make_room(nbytes)
        self.map[key] = [nbytes, False, tick]
        self.used += nbytes
        return nbytes

    def _touch_write(self, key, nbytes):
        tick = self._next_tick()
        if key in self.map:
            e = self.map[key]
            e[2] = tick
            if e[1]:
                return 0
            e[1] = True
            return nbytes
        if nbytes <= self.budget:
            self._make_room(nbytes)
            self.map[key] = [nbytes, True, tick]
            self.used += nbytes
        return nbytes

    def access(self, ins, out=None):
        """ins: [(key, bytes)], out: (key, bytes) | None -> (h2d, d2h, full)."""
        h2d = d2h = full = 0
        for key, nbytes in ins:
            full += nbytes
            h2d += self._touch_read(key, nbytes)
        if out is not None:
            key, nbytes = out
            full += nbytes
            d2h += self._touch_write(key, nbytes)
        return h2d, d2h, full

    def host_read(self, key):
        if key in self.map:
            self.map[key][1] = False

    def host_mut(self, key):
        if key in self.map:
            self.used -= self.map.pop(key)[0]


def _random_trace(rng, steps=400, nbufs=24, nbytes=512):
    trace = []
    for _ in range(steps):
        kind = rng.choice(["op", "host_read", "host_mut"], p=[0.8, 0.1, 0.1])
        if kind == "op":
            ins = [(int(k), nbytes) for k in rng.choice(nbufs, size=rng.integers(1, 4))]
            out = (int(rng.integers(nbufs)), nbytes) if rng.random() < 0.7 else None
            trace.append(("op", ins, out))
        else:
            trace.append((kind, int(rng.integers(nbufs)), None))
    return trace


def _replay(cache, trace):
    charged = full = 0
    for kind, a, c in trace:
        if kind == "op":
            h2d, d2h, f = cache.access(a, c)
            assert h2d + d2h <= f, "a call can never charge above streaming"
            charged += h2d + d2h
            full += f
        elif kind == "host_read":
            cache.host_read(a)
        else:
            cache.host_mut(a)
        assert cache.used <= cache.budget
    return charged, full


@pytest.mark.parametrize("seed", range(8))
def test_cache_charges_at_most_streaming_and_respects_budget(seed):
    rng = np.random.default_rng(seed)
    trace = _random_trace(rng)
    for budget in (1024, 4096, 1 << 20):
        charged, full = _replay(TileCache(budget), trace)
        assert charged <= full
    # With a big budget something must actually be saved.
    charged, full = _replay(TileCache(1 << 20), trace)
    assert charged < full


@pytest.mark.parametrize("seed", range(8))
def test_bigger_cache_never_charges_more(seed):
    # LRU is a stack algorithm over the uniform-size entries the engines
    # use, so the inclusion property holds: charges are monotone in budget.
    rng = np.random.default_rng(100 + seed)
    trace = _random_trace(rng)
    prev = None
    for budget in (512, 1024, 2048, 8192, 1 << 16):
        charged, _ = _replay(TileCache(budget), trace)
        if prev is not None:
            assert charged <= prev, f"budget {budget} charged more than smaller"
        prev = charged


def test_writeback_paid_once_per_dirty_period():
    c = TileCache(1 << 20)
    out = ("c", 4096)
    assert c.access([out], out) == (4096, 4096, 8192)  # fill + write-back slot
    assert c.access([out], out) == (0, 0, 8192)  # same dirty period
    c.host_read("c")  # host observes -> period closed
    assert c.access([out], out) == (0, 4096, 8192)  # new period
    c.host_mut("c")  # host mutates -> device copy dropped
    assert c.access([out], out) == (4096, 4096, 8192)


def test_oversized_buffer_streams_without_residency():
    c = TileCache(1000)
    big = ("big", 4096)
    assert c.access([big], big) == (4096, 4096, 8192)
    assert len(c.map) == 0


# ---------------------------------------------------------------------------
# 3. fused BLAS-1 bit-identity (linalg/blas1.rs + the solver rewrites)
# ---------------------------------------------------------------------------


def _dot4(x, y):
    """linalg/blas1.rs::dot — 4-way unrolled accumulation, bit-exact."""
    n = len(x)
    chunks = n // 4
    a0 = a1 = a2 = a3 = type(x[0])(0)
    for cidx in range(chunks):
        i = cidx * 4
        a0 += x[i] * y[i]
        a1 += x[i + 1] * y[i + 1]
        a2 += x[i + 2] * y[i + 2]
        a3 += x[i + 3] * y[i + 3]
    acc = (a0 + a1) + (a2 + a3)
    for i in range(chunks * 4, n):
        acc += x[i] * y[i]
    return acc


@pytest.mark.parametrize("dt", [np.float64, np.float32])
def test_fused_primitives_bitwise_equal_unfused(dt):
    rng = np.random.default_rng(5)
    x = rng.standard_normal(37).astype(dt)
    y0 = rng.standard_normal(37).astype(dt)
    beta = dt(0.8311)
    alpha = dt(-0.25)
    # xpay == scal-then-axpy: x + beta*y vs (beta*y) + 1*x — IEEE addition
    # and multiplication commute, so the bits agree.
    unfused = y0 * beta
    unfused = unfused + dt(1.0) * x
    fused = x + beta * y0
    assert unfused.tobytes() == fused.tobytes()
    # axpy_norm2 == axpy-then-dot (same dot, same order).
    yu = y0 + alpha * x
    assert _dot4(yu, yu) == _dot4((y0 + alpha * x), (y0 + alpha * x))
    # norm2_dot lanes are the plain dots; dot(w, r) == dot(r, w) bitwise.
    assert _dot4(x, y0) == _dot4(y0, x)


def _cg(a, b, iters, fused):
    """Serial CG over numpy float64, unfused vs fused update sequences —
    mirrors solvers/iterative/cg.rs before/after the rewrite."""
    n = len(b)
    x = np.zeros(n)
    r = b.copy()
    p = r.copy()
    rr = _dot4(r, r)
    for _ in range(iters):
        ap = a @ p
        pap = _dot4(p, ap)
        alpha = rr / pap
        x = x + alpha * p
        if fused:
            r = r + (-alpha) * ap  # axpy half of the fused kernel
            rr_new = _dot4(r, r)  # dot half
        else:
            r = r + (-alpha) * ap
            rr_new = _dot4(r, r)
        beta = rr_new / rr
        rr = rr_new
        if fused:
            p = r + beta * p  # xpay
        else:
            p = p * beta
            p = p + 1.0 * r
    return x, r, p


def test_cg_iterates_bit_identical_fused_vs_unfused():
    rng = np.random.default_rng(11)
    n = 48
    m = rng.standard_normal((n, n))
    a = m @ m.T + n * np.eye(n)
    b = rng.standard_normal(n)
    xu, ru, pu = _cg(a, b, 25, fused=False)
    xf, rf, pf = _cg(a, b, 25, fused=True)
    assert xu.tobytes() == xf.tobytes()
    assert ru.tobytes() == rf.tobytes()
    assert pu.tobytes() == pf.tobytes()


def _binomial_reduce(contribs, root=0):
    """Transcription of comm/collectives.rs::reduce_vec: binomial tree,
    element-wise combine in ascending-mask partner order."""
    p = len(contribs)
    vals = [np.array(c, dtype=np.float64) for c in contribs]
    alive = list(range(p))
    mask = 1
    while mask < p:
        for me in range(p):
            rel = (me + p - root) % p
            if rel & mask == 0:
                peer_rel = rel | mask
                if peer_rel < p:
                    src = (peer_rel + root) % p
                    vals[me] = vals[me] + vals[src]
            # senders drop out (their value was consumed)
        mask <<= 1
    del alive
    return vals[root]


def test_two_lane_allreduce_lanes_bitwise_equal_scalar_allreduces():
    # BiCGSTAB's fused reduction pairs ride one two-lane allreduce; each
    # lane must combine on the same tree as a scalar allreduce would, so
    # the values are bit-identical to the unfused pair of reductions.
    rng = np.random.default_rng(21)
    for p in (2, 3, 4, 7, 8):
        a = rng.standard_normal(p)  # lane 1 partials, one per rank
        b = rng.standard_normal(p)  # lane 2 partials
        fused = _binomial_reduce([np.array([x, y]) for x, y in zip(a, b)])
        lane1 = _binomial_reduce([np.array([x]) for x in a])
        lane2 = _binomial_reduce([np.array([y]) for y in b])
        assert fused[0].tobytes() == lane1[0].tobytes()
        assert fused[1].tobytes() == lane2[0].tobytes()


# ---------------------------------------------------------------------------
# 4. branch-free 4-wide GEMM micro-kernel (linalg/blas3.rs)
# ---------------------------------------------------------------------------

MC, KC = 64, 128


def _gemm_block(n, k, a, b, c, i0, i1, p0, p1, sub):
    """Transcription of the new gemm_block: no zero-skip, 4-wide j-loop."""
    for i in range(i0, i1):
        arow = a[i * k:(i + 1) * k]
        crow = c[i * n:(i + 1) * n]
        for p in range(p0, p1):
            aip = -arow[p] if sub else arow[p]
            brow = b[p * n:(p + 1) * n]
            chunks = n // 4
            for q in range(chunks):
                j = q * 4
                crow[j] += aip * brow[j]
                crow[j + 1] += aip * brow[j + 1]
                crow[j + 2] += aip * brow[j + 2]
                crow[j + 3] += aip * brow[j + 3]
            for j in range(chunks * 4, n):
                crow[j] += aip * brow[j]


def _blocked(m, n, k, a, b, c, sub):
    for i0 in range(0, m, MC):
        i1 = min(i0 + MC, m)
        for p0 in range(0, k, KC):
            p1 = min(p0 + KC, k)
            _gemm_block(n, k, a, b, c, i0, i1, p0, p1, sub)


@pytest.mark.parametrize("shape", [(3, 4, 5), (17, 9, 33), (8, 7, 130), (70, 6, 129)])
def test_unrolled_gemm_kernel_matches_numpy(shape):
    m, n, k = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    a = rng.standard_normal(m * k)
    b = rng.standard_normal(k * n)
    # gemm_add semantics: C += A·B on a random C.
    c0 = rng.standard_normal(m * n)
    c = c0.copy()
    _blocked(m, n, k, a, b, c, sub=False)
    want = c0 + (a.reshape(m, k) @ b.reshape(k, n)).ravel()
    np.testing.assert_allclose(c, want, rtol=1e-10, atol=1e-10)
    # gemm_sub semantics: C -= A·B.
    c = c0.copy()
    _blocked(m, n, k, a, b, c, sub=True)
    want = c0 - (a.reshape(m, k) @ b.reshape(k, n)).ravel()
    np.testing.assert_allclose(c, want, rtol=1e-10, atol=1e-10)


def test_unrolled_gemm_kernel_zero_heavy_operands():
    # The removed skip branch: zero-heavy A must still produce exact rows.
    m, n, k = 19, 23, 17
    rng = np.random.default_rng(3)
    a = rng.standard_normal(m * k)
    a[np.arange(m * k) % 3 != 0] = 0.0
    b = rng.standard_normal(k * n)
    c = np.zeros(m * n)
    _blocked(m, n, k, a, b, c, sub=False)
    want = (a.reshape(m, k) @ b.reshape(k, n)).ravel()
    np.testing.assert_allclose(c, want, rtol=1e-12, atol=1e-12)
