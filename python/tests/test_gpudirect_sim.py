"""No-toolchain verification of the GPUDirect wire PR (rust DESIGN.md §16).

Five independent oracles:

1. **Model-twin inequalities** — exactly what `cargo bench --bench
   gpudirect` asserts: `gpudirect <= host-staged` on every emitted
   configuration, strictly smaller wherever a device-dirty payload hits
   the wire (`wire_stage > 0`), an exact wash everywhere else, and the
   sparse halo rows always a wash.
2. **Strictness predicates** — the stage term is positive exactly where
   the runtime routing sends device-dirty buffers: LU at `gpu ∧ pr > 1`,
   Cholesky at `gpu ∧ P > 1`, CG/BiCGSTAB at `gpu ∧ pc > 1`, SUMMA never.
3. **Committed artifact** — `BENCH_gpudirect.json` must be byte-identical
   to what the model mirror produces, with a valid schema.
4. **Off-bench sweep** — across odd sizes, tiles and meshes: a host-clean
   payload (host profile, `pcie_bw = 0`) is an *exact* wash — the
   gpudirect twin equals the host-staged sum bitwise — and on the
   accelerated arm the residual of each wire payload never exceeds its
   stage (the PCIe leg can only shrink by riding under the NIC leg).
5. **Batched BiCGSTAB twin** — `bicgstab_makespan_batched` is the
   single-RHS BiCGSTAB arm bit for bit at k = 1 and strictly amortizes at
   k > 1 (the serving scheduler's new pricer).
"""

import json
import pathlib

import model_mirror as mm

LE_SLACK = 1.0 + 1e-9


def _wash(a, b):
    return abs(a - b) <= 1e-12 * max(b, 1.0)


# ---------------------------------------------------------------------------
# 1 + 2. model twins — bench acceptance shape and strictness predicates
# ---------------------------------------------------------------------------


def test_gpudirect_bench_acceptance_shape():
    rows = mm.gpudirect_rows()
    assert len(rows) == len(mm.PAPER_RANKS) * 2 * 5  # ranks x engines x kernels
    for (kernel, engine, n, ranks, pr, pc, stage, staged, g, strict) in rows:
        assert stage >= 0.0
        assert g <= staged * LE_SLACK, (
            f"{kernel} {engine} P={ranks}: gpudirect {g} > staged {staged}"
        )
        if strict:
            assert stage > 0.0
            assert g < staged, (
                f"{kernel} {engine} P={ranks}: a dirty payload hit the wire, "
                f"gpudirect must strictly win"
            )
        else:
            assert stage == 0.0
            assert _wash(g, staged), (
                f"{kernel} {engine} P={ranks}: no wire traffic must be a wash"
            )


def test_gpudirect_strict_exactly_where_dirty_payloads_hit_the_wire():
    for (kernel, engine, n, ranks, pr, pc, stage, staged, g, strict) in (
        mm.gpudirect_rows()
    ):
        gpu = engine == "MPI+CUDA"
        if kernel == "LU":
            want = gpu and pr > 1
        elif kernel == "Cholesky":
            want = gpu and ranks > 1
        elif kernel in ("CG", "BiCGSTAB"):
            want = gpu and pc > 1
        else:
            assert kernel == "SUMMA"
            want = False  # read-only host-clean panels, always a wash
        assert strict == want, f"{kernel} {engine} P={ranks} ({pr}x{pc})"


def test_gpudirect_sparse_rows_always_a_wash():
    rows = mm.gpudirect_sparse_rows()
    assert len(rows) == len(mm.PAPER_RANKS) * len(mm.HALO_STENCILS) * 2
    for (stencil, method, grid, n, nnz, ranks, staged, g) in rows:
        # Host-arm operands, host-clean ghost segments: the halo wire
        # composes with GPUDirect as an exact wash.
        assert _wash(g, staged), f"{stencil} {method} P={ranks}"


def test_bicgstab_wire_costs_twice_cg():
    # Two matvecs per BiCGSTAB iteration vs one per CG: the staging legs
    # double, so wherever CG's stage is positive BiCGSTAB's is larger.
    p = mm.params(16, gpu=True)
    cg = mm.iter_wire_stage("cg", mm.PAPER_N, 100, p, 4)
    bi = mm.iter_wire_stage("bicgstab", mm.PAPER_N, 100, p, 4)
    assert cg > 0.0
    assert bi == 2.0 * cg


# ---------------------------------------------------------------------------
# 3. committed artifact
# ---------------------------------------------------------------------------


def test_gpudirect_artifact_bytes():
    root = pathlib.Path(__file__).resolve().parents[2]
    assert (
        (root / "BENCH_gpudirect.json").read_text() == mm.render_gpudirect_json()
    )


def test_gpudirect_artifact_is_valid_json_with_expected_schema():
    root = pathlib.Path(__file__).resolve().parents[2]
    doc = json.loads((root / "BENCH_gpudirect.json").read_text())
    assert doc["network"] == "gigabit_ethernet"
    assert doc["tile"] == 256
    assert doc["iters"] == mm.GPUDIRECT_ITERS
    entries, sparse = doc["entries"], doc["sparse"]
    assert len(entries) == 50 and len(sparse) == 20
    for e in entries:
        assert e["pr"] * e["pc"] == e["ranks"]
        assert e["gpudirect_secs"] <= e["staged_secs"] * LE_SLACK
        assert e["strict"] == (e["wire_stage_secs"] > 0.0)
        assert abs(
            e["saved_frac"] - (1.0 - e["gpudirect_secs"] / e["staged_secs"])
        ) <= 5e-5  # the emitted ratio is rounded to 4 decimals
    for e in sparse:
        assert e["n"] == e["grid"] ** (2 if e["stencil"] == "poisson2d" else 3)
        assert e["gpudirect_secs"] == e["staged_secs"]  # exact wash, literal


# ---------------------------------------------------------------------------
# 4. off-bench sweep — host-clean washes, residual <= stage
# ---------------------------------------------------------------------------


def test_host_clean_payloads_are_an_exact_wash_across_the_sweep():
    # On the host profile pcie_bw = 0: wire_payload is (0, 0) identically,
    # so every gpudirect twin equals its host-staged sum bitwise.
    for ranks in (1, 2, 3, 5, 8):
        pr, pc = mm.near_square(ranks)
        p = mm.ModelParams(
            tile=96, pr=pr, pc=pc, net=mm.gigabit_ethernet(),
            engine=mm.q6600_atlas(), panel_cpu=mm.q6600_atlas(),
            swap_fraction=0.5,
        )
        for n in (960, 3_072):
            assert mm.wire_payload(p, n, 4) == (0.0, 0.0)
            assert mm.lu_wire_stage(n, p, 4) == 0.0
            assert mm.lu_makespan_gpudirect(n, p, 4) == mm.lu_makespan_prefetch(n, p, 4)
            assert mm.chol_wire_stage(n, p, 4) == 0.0
            assert mm.chol_makespan_gpudirect(n, p, 4) == mm.chol_makespan_prefetch(
                n, p, 4
            )
            for m in ("cg", "bicgstab", "pipecg"):
                assert mm.iter_wire_stage(m, n, 50, p, 4) == 0.0
                assert mm.iter_makespan_gpudirect(
                    m, n, 50, 30, p, 4
                ) == mm.iter_makespan_prefetch(m, n, 50, 30, p, 4)


def test_residual_never_exceeds_stage_on_the_accelerated_arm():
    # max(0, xfer - msg) <= xfer termwise; strict because a send's NIC leg
    # (alpha + bytes * beta) is never free.
    for ranks in (2, 4, 6, 16):
        pr, pc = mm.near_square(ranks)
        p = mm.ModelParams(
            tile=128, pr=pr, pc=pc, net=mm.gigabit_ethernet(),
            engine=mm.gtx280_cublas(), panel_cpu=mm.q6600_atlas(),
            swap_fraction=0.5,
        )
        for elems in (1, 128, 128 * 128, 10_000):
            stage, residual = mm.wire_payload(p, elems, 4)
            assert stage > 0.0
            assert 0.0 <= residual < stage
        for n in (2_048, 10_240):
            for twin, staged in (
                (mm.lu_makespan_gpudirect(n, p, 4),
                 mm.lu_makespan_prefetch(n, p, 4) + mm.lu_wire_stage(n, p, 4)),
                (mm.chol_makespan_gpudirect(n, p, 4),
                 mm.chol_makespan_prefetch(n, p, 4) + mm.chol_wire_stage(n, p, 4)),
                (mm.iter_makespan_gpudirect("bicgstab", n, 50, 30, p, 4),
                 mm.iter_makespan_prefetch("bicgstab", n, 50, 30, p, 4)
                 + mm.iter_wire_stage("bicgstab", n, 50, p, 4)),
            ):
                assert twin <= staged * LE_SLACK


def test_methods_outside_the_fused_flow_keep_host_staged_accounting():
    p = mm.params(16, gpu=True)
    for m in ("bicg", "gmres"):
        assert mm.iter_wire_stage(m, mm.PAPER_N, 100, p, 4) == 0.0
        assert mm.iter_makespan_gpudirect(
            m, mm.PAPER_N, 100, 30, p, 4
        ) == mm.iter_makespan_prefetch(m, mm.PAPER_N, 100, 30, p, 4)


def test_summa_and_sparse_wire_stages_are_identically_zero():
    for gpu in (False, True):
        p = mm.params(4, gpu)
        assert mm.summa_wire_stage(16_384, p, 4) == 0.0
        assert mm.summa_makespan_gpudirect(16_384, p, 4, True) == (
            mm.summa_makespan_prefetch(16_384, p, 4, True)
        )
        assert mm.sparse_iter_wire_stage(1_000_000, 4_996_000, p, 8) == 0.0
        assert mm.sparse_iter_makespan_gpudirect(
            "cg", 1_000_000, 4_996_000, 100, 30, p, 8
        ) == mm.sparse_iter_makespan_prefetch(
            "cg", 1_000_000, 4_996_000, 100, 30, p, 8
        )


# ---------------------------------------------------------------------------
# 5. the batched BiCGSTAB twin (the serving pricer's new arm)
# ---------------------------------------------------------------------------


def test_batched_bicgstab_exact_at_k1_and_amortizes_above():
    for ranks in mm.PAPER_RANKS:
        for gpu in (False, True):
            p = mm.params(ranks, gpu)
            single = mm.iter_makespan("bicgstab", mm.PAPER_N, 100, 30, p, 4)
            assert mm.bicgstab_makespan_batched(mm.PAPER_N, 1, 100, p, 4) == single
            for k in (2, 4, 8):
                batched = mm.bicgstab_makespan_batched(mm.PAPER_N, k, 100, p, 4)
                assert batched < k * single, f"P={ranks} gpu={gpu} k={k}"


def test_serving_price_routes_bicgstab_through_the_batched_twin():
    p = mm.params(mm.SERVE_RANKS, gpu=True)
    members = [
        {"n": mm.SERVE_BASE_N, "method": "bicgstab"} for _ in range(4)
    ]
    assert mm._serve_price(p, members) == mm.bicgstab_makespan_batched(
        mm.SERVE_BASE_N, 4, mm.SERVE_ITERS, p, 4
    )
    assert mm._serve_price(p, members) < 4 * mm.iter_makespan(
        "bicgstab", mm.SERVE_BASE_N, mm.SERVE_ITERS, 30, p, 4
    )
