"""No-toolchain verification of the neighbor-exchange (halo) PR (rust
DESIGN.md §15).

Five independent oracles:

1. **Model-twin inequalities** — exactly what `cargo bench --bench halo`
   asserts (`halo <= allgather` on every emitted configuration, strict
   wherever the mesh has more than one process row, an exact wash at one
   process row), over every bench row.
2. **Committed artifact** — `BENCH_halo.json` must be byte-identical to
   what the model mirror produces.
3. **Off-bench sweep** — 1-D/2-D/3-D stencils, odd grids, odd tiles, odd
   process-row counts (including pr = 3 and 5): the halo never models
   slower than the allgather, degenerates to it exactly at pr = 1, and
   the enumerated totals match the closed-form nnz counts.
4. **Surface enumeration vs brute force** — `stencil_halo_counts` against
   an independent coordinate-walk construction of the stencil pattern:
   per-rank ghost/send/neighbor counts, global send/ghost conservation.
5. **Plan index laws + bit-identity** — a transcription of
   `HaloPlan::build`'s index logic on random sparsity (recv lists
   partition the ghosts by owner, peers own what they serve, send is the
   transpose of recv) and of the monotone renumbering
   (`owned_local_col`): accumulating each row in renumbered column order
   reproduces the allgather split-half sums bit for bit in float64.
"""

import json
import pathlib
import random

import model_mirror as mm

LE_SLACK = 1.0 + 1e-9


# ---------------------------------------------------------------------------
# 1 + 2. model twins — the bench acceptance shape and the committed artifact
# ---------------------------------------------------------------------------


def test_halo_bench_acceptance_shape():
    rows = mm.halo_rows()
    assert len(rows) == len(mm.PAPER_RANKS) * len(mm.HALO_STENCILS) * 2
    for (stencil, method, grid, n, nnz, ranks, pr, neighbors, ghost,
         diag_frac, ag, ha, strict) in rows:
        assert 0.0 < diag_frac <= 1.0
        assert ha <= ag * LE_SLACK, (
            f"{stencil} {method} P={ranks}: halo {ha} > allgather {ag}"
        )
        if strict:
            assert pr > 1
            assert ha < ag, (
                f"{stencil} {method} P={ranks} (pr={pr}): the halo must "
                f"strictly win"
            )
        else:
            # One process row: both wires are zero — an exact wash, not a
            # fabricated win.
            assert pr == 1 and neighbors == 0 and ghost == 0
            assert abs(ha - ag) <= 1e-12 * ag, (
                f"{stencil} {method} P={ranks}: must be a wash"
            )


def test_halo_strict_everywhere_multirow_on_gigabit():
    # The acceptance bar from the issue: halo <= allgather everywhere,
    # strict at P >= 4 (near_square folds P = 2 into one process row).
    for row in mm.halo_rows():
        ranks, pr = row[5], row[6]
        assert (pr > 1) == (ranks >= 4)
        if ranks >= 4:
            assert row[11] < row[10]


def test_halo_artifact_bytes():
    root = pathlib.Path(__file__).resolve().parents[2]
    assert (root / "BENCH_halo.json").read_text() == mm.render_halo_json()


def test_halo_artifact_is_valid_json_with_expected_schema():
    root = pathlib.Path(__file__).resolve().parents[2]
    doc = json.loads((root / "BENCH_halo.json").read_text())
    assert doc["network"] == "gigabit_ethernet"
    entries = doc["entries"]
    assert len(entries) == 20
    for e in entries:
        assert e["n"] == e["grid"] ** (2 if e["stencil"] == "poisson2d" else 3)
        assert e["halo_secs"] <= e["allgather_secs"] * LE_SLACK
        assert abs(
            e["saved_frac"] - (1.0 - e["halo_secs"] / e["allgather_secs"])
        ) <= 5e-5  # the emitted ratio is rounded to 4 decimals


# ---------------------------------------------------------------------------
# 3. off-bench sweep — dimensions, odd grids/tiles/meshes, degenerates
# ---------------------------------------------------------------------------


def _sweep_params(tile, pr):
    return mm.ModelParams(
        tile=tile, pr=pr, pc=1, net=mm.gigabit_ethernet(),
        engine=mm.q6600_atlas(), panel_cpu=mm.q6600_atlas(),
        swap_fraction=0.5,
    )


def test_halo_never_loses_across_the_sweep():
    for grid, dim in ((101, 1), (21, 2), (9, 3)):
        n = grid**dim
        for tile in (7, 16):
            for pr in (1, 2, 3, 5):
                p = _sweep_params(tile, pr)
                h = mm.stencil_halo_counts(grid, dim, tile, pr)
                diag_frac = h["diag_nnz"] / h["total_nnz"]
                for method in ("cg", "bicgstab"):
                    ag = mm.sparse_iter_makespan_split(
                        method, n, h["total_nnz"], 50, diag_frac, p, 8
                    )
                    ha = mm.sparse_iter_makespan_halo(
                        method, n, h["total_nnz"], 50, diag_frac,
                        h["neighbors"], h["ghost_elems"], p, 8
                    )
                    assert ha <= ag * LE_SLACK, (
                        f"dim={dim} g={grid} t={tile} pr={pr} {method}"
                    )
                    if pr == 1:
                        # Serial: no neighbors, both wires zero — the halo
                        # cost degenerates to the allgather cost exactly.
                        assert h["neighbors"] == 0 and h["ghost_elems"] == 0
                        assert ha == ag


def test_halo_wire_shape():
    p = _sweep_params(16, 4)
    # No neighbors -> no wire, regardless of ghost count bookkeeping.
    assert mm.halo_wire(p, 0, 0, 8) == 0.0
    # One neighbor, one segment: exactly one p2p message.
    assert mm.halo_wire(p, 1, 100, 8) == p.msg(100, 8)
    # Splitting the same surface across more peers pays more latency.
    assert mm.halo_wire(p, 2, 100, 8) == 2.0 * p.msg(50, 8)
    assert mm.halo_wire(p, 2, 100, 8) > mm.halo_wire(p, 1, 100, 8)


def test_nnz_closed_forms_match_the_enumeration():
    for grid, dim, nnz_fn in (
        (23, 1, mm.poisson1d_nnz), (11, 2, mm.poisson2d_nnz),
        (5, 3, mm.poisson3d_nnz),
    ):
        h = mm.stencil_halo_counts(grid, dim, 4, 3)
        assert h["total_nnz"] == nnz_fn(grid)
        # diag + off partitions the stored entries; the off-block share is
        # bounded by (in fact, counted with multiplicity at least) the
        # ghost surface.
        assert 0 < h["diag_nnz"] <= h["total_nnz"]


# ---------------------------------------------------------------------------
# 4. surface enumeration vs brute force
# ---------------------------------------------------------------------------


def _stencil_rows_bruteforce(g, dim):
    """Independent construction of the dim-D Poisson pattern: walk grid
    coordinates, couple +-1 along each axis (no wraparound)."""
    n = g**dim
    rows = []
    for i in range(n):
        coords = []
        rest = i
        for _ in range(dim):
            coords.append(rest % g)
            rest //= g
        cols = [i]
        for ax in range(dim):
            s = g**ax
            if coords[ax] > 0:
                cols.append(i - s)
            if coords[ax] < g - 1:
                cols.append(i + s)
        rows.append(sorted(cols))
    return rows


def _surface_from_rows(rows, tile, pr):
    """Per-rank ghost/send/neighbor counts straight from a pattern."""
    def owner(x):
        return (x // tile) % pr

    ghost = [set() for _ in range(pr)]
    pairs = [set() for _ in range(pr)]
    diag_nnz = 0
    for i, cols in enumerate(rows):
        r = owner(i)
        for c in cols:
            if owner(c) == r:
                diag_nnz += 1
            else:
                ghost[r].add(c)
                pairs[r].add(owner(c))
                pairs[owner(c)].add(r)
    # send[q] = one copy of each of q's columns per rank that ghosts it.
    send = [0] * pr
    for r in range(pr):
        for c in ghost[r]:
            send[owner(c)] += 1
    return ghost, send, pairs, diag_nnz


def test_stencil_counts_match_brute_force():
    for g, dim in ((13, 1), (7, 2), (4, 3)):
        for tile in (2, 3, 4):
            for pr in (1, 2, 3, 4):
                h = mm.stencil_halo_counts(g, dim, tile, pr)
                rows = _stencil_rows_bruteforce(g, dim)
                ghost, send, pairs, diag_nnz = _surface_from_rows(rows, tile, pr)
                label = f"g={g} dim={dim} t={tile} pr={pr}"
                assert h["ghost_elems"] == max(len(s) for s in ghost), label
                assert h["send_elems"] == max(send), label
                assert h["neighbors"] == max(len(s) for s in pairs), label
                assert h["diag_nnz"] == diag_nnz, label
                assert h["total_nnz"] == sum(len(r) for r in rows), label
                # Conservation: every ghosted element is sent exactly once
                # per ghosting rank.
                assert sum(len(s) for s in ghost) == sum(send), label


# ---------------------------------------------------------------------------
# 5. plan index laws + renumbering bit-identity on random sparsity
# ---------------------------------------------------------------------------


def _build_plans(rows_cols, tile, pr):
    """Transcription of HaloPlan::build's index logic for all ranks at
    once: (ghost_cols, recv, send) per process row — `send` computed the
    way the rust handshake learns it (the transpose of everyone's recv)."""
    def owner(x):
        return (x // tile) % pr

    ghost = [set() for _ in range(pr)]
    for i, cols in enumerate(rows_cols):
        r = owner(i)
        for c in cols:
            if owner(c) != r:
                ghost[r].add(c)
    ghosts = [sorted(s) for s in ghost]
    recv = [[[] for _ in range(pr)] for _ in range(pr)]
    for r in range(pr):
        for c in ghosts[r]:
            recv[r][owner(c)].append(c)
    send = [[recv[q][r] for q in range(pr)] for r in range(pr)]
    return ghosts, recv, send


def _random_pattern(rng, n):
    rows = []
    for i in range(n):
        cols = {i}
        for _ in range(rng.randrange(0, 4)):
            cols.add(rng.randrange(n))
        rows.append(sorted(cols))
    return rows


def test_plan_index_laws_on_random_sparsity():
    rng = random.Random(0xA105EED)
    for _ in range(25):
        n = rng.randrange(8, 41)
        tile = rng.randrange(2, 6)
        pr = rng.randrange(2, 5)
        rows = _random_pattern(rng, n)
        ghosts, recv, send = _build_plans(rows, tile, pr)

        def owner(x):
            return (x // tile) % pr

        for r in range(pr):
            # recv partitions the ghosts by owner: disjoint, sorted,
            # every col actually owned by the peer it is charged to.
            seen = []
            for q in range(pr):
                assert recv[r][q] == sorted(recv[r][q])
                for c in recv[r][q]:
                    assert owner(c) == q != r
                seen.extend(recv[r][q])
            assert sorted(seen) == ghosts[r]
            assert recv[r][r] == [] and send[r][r] == []
            # Coverage: ghosts are exactly the distinct off-block columns.
            want = sorted({
                c
                for i, cols in enumerate(rows) if owner(i) == r
                for c in cols if owner(c) != r
            })
            assert ghosts[r] == want
        # Symmetry across ranks (what the rust handshake establishes on
        # the wire): i's recv-from-j is j's send-to-i.
        for i in range(pr):
            for j in range(pr):
                assert recv[i][j] == send[j][i]
        # Conservation: everything sent is received somewhere.
        total_sent = sum(len(send[r][q]) for r in range(pr) for q in range(pr))
        assert total_sent == sum(len(g) for g in ghosts)


def _owned_local_col(c, tile, pr):
    """rust owned_local_col: tile c/t sits at local tile (c/t)/pr under the
    round-robin layout — strictly monotone over owned columns."""
    return (c // tile) // pr * tile + c % tile


def test_renumbered_accumulation_is_bit_identical():
    # The bit-identity contract: both renumberings are strictly monotone,
    # so summing each row's entries in renumbered column order reproduces
    # the allgather split-half sums bit for bit.
    rng = random.Random(0x5EED0)
    for _ in range(25):
        n = rng.randrange(8, 41)
        tile = rng.randrange(2, 6)
        pr = rng.randrange(2, 5)
        rows = _random_pattern(rng, n)
        vals = {
            (i, c): rng.uniform(-1.0, 1.0) for i, cols in enumerate(rows)
            for c in cols
        }
        x = [rng.uniform(-1.0, 1.0) for _ in range(n)]

        def owner(c):
            return (c // tile) % pr

        ghosts, _, _ = _build_plans(rows, tile, pr)
        for r in range(pr):
            # Monotonicity of both maps on this rank's columns.
            owned = [c for c in range(n) if owner(c) == r]
            loc = [_owned_local_col(c, tile, pr) for c in owned]
            assert loc == sorted(set(loc))
            slot = {c: k for k, c in enumerate(ghosts[r])}
            for i, cols in enumerate(rows):
                if owner(i) != r:
                    continue
                # Allgather split halves: global column order.
                diag_ref = 0.0
                off_ref = 0.0
                for c in cols:
                    if owner(c) == r:
                        diag_ref += vals[(i, c)] * x[c]
                    else:
                        off_ref += vals[(i, c)] * x[c]
                # Halo path: diag sorted by compact local col, off by
                # ghost slot.
                diag_entries = sorted(
                    ((_owned_local_col(c, tile, pr), vals[(i, c)], x[c])
                     for c in cols if owner(c) == r),
                )
                off_entries = sorted(
                    ((slot[c], vals[(i, c)], x[c])
                     for c in cols if owner(c) != r),
                )
                diag_halo = 0.0
                for _k, v, xv in diag_entries:
                    diag_halo += v * xv
                off_halo = 0.0
                for _k, v, xv in off_entries:
                    off_halo += v * xv
                assert diag_halo == diag_ref  # bitwise: same fp sequence
                assert off_halo == off_ref
                assert diag_halo + off_halo == diag_ref + off_ref
