"""No-toolchain verification of the mixed-precision PR (rust DESIGN.md §17).

Five independent oracles:

1. **Model-twin inequalities** — exactly what `cargo bench --bench mixed`
   asserts: `mixed <= f64` on every emitted configuration, strictly
   smaller on the accelerated arm (the dtype x profile gate is open:
   SGEMM outruns DGEMM and every PCIe/wire byte halves), and an *exact*
   wash on the host arm, where the gate closes and the mixed twin IS the
   uniform gpudirect twin.
2. **Gate predicates** — `mixed_capable` (f64 only: f32 is its own
   storage floor), `mixed_advantage` (GTX 280 yes, Q6600 no), and their
   conjunction `model_mixed_engaged`, matched against the strict flags.
3. **Committed artifact** — `BENCH_mixed.json` must be byte-identical to
   what the model mirror produces, with a valid schema and re-checked
   inequalities straight from the parsed JSON.
4. **Model structure** — the refined twins decompose exactly into
   demote + narrow factor/solve + 3·(wide sweep + 2 resident
   substitutions); the resident substitution drops only the factor-tile
   broadcast leg (equal to the streaming `trsv` on one-column meshes,
   strictly cheaper on wider ones); paper-scale P = 16 CUDA speedups
   clear 1.5x.
5. **Numeric refinement simulation** — an f32-factorization / f64-sweep
   iterative refinement (numpy mirror of `plu_solve_refined`): on a
   well-conditioned operator it meets the wide `8·n·u` backward bound
   within the sweep budget and recovers the solution far beyond f32
   accuracy; on a Hilbert system the stagnation guard reports failure
   instead of lying — the live cluster's wide-fallback trigger.
"""

import json
import pathlib

import numpy as np

import model_mirror as mm

LE_SLACK = 1.0 + 1e-9

REFINE_MAX_SWEEPS = 10  # solvers/direct/refined.rs
REFINE_STAGNATION = 0.5
U64 = 2.0 ** -53


def refine_bound(n):
    """rust refine_bound::<S>: 8·n·u in the wide dtype (S::Hi is f64 for
    both f32 and f64 operands)."""
    return 8.0 * n * U64


# ---------------------------------------------------------------------------
# 1 + 2. model twins — bench acceptance shape and gate predicates
# ---------------------------------------------------------------------------


def _check_row(label, wide, mixed, strict):
    assert mixed <= wide * LE_SLACK, f"{label}: mixed {mixed} > f64 {wide}"
    if strict:
        assert mixed < wide, f"{label}: gate open, mixed must strictly win"
    else:
        assert mixed == wide, f"{label}: gate closed, must be the uniform twin"


def test_mixed_bench_acceptance_shape_dense():
    rows = mm.mixed_rows()
    assert len(rows) == len(mm.PAPER_RANKS) * 2 * 4  # ranks x engines x kernels
    for (kernel, engine, n, ranks, pr, pc, wide, mixed, strict) in rows:
        assert n == mm.PAPER_N and pr * pc == ranks
        _check_row(f"{kernel} {engine} P={ranks}", wide, mixed, strict)


def test_mixed_bench_acceptance_shape_sparse():
    rows = mm.mixed_sparse_rows()
    assert len(rows) == len(mm.PAPER_RANKS) * 2 * len(mm.HALO_STENCILS) * 2
    for (stencil, method, grid, n, nnz, engine, ranks, wide, mixed, strict) in rows:
        assert n == grid ** (2 if stencil == "poisson2d" else 3)
        _check_row(f"{stencil} {method} {engine} P={ranks}", wide, mixed, strict)


def test_strict_exactly_where_the_gate_opens():
    for row in mm.mixed_rows():
        engine, strict = row[1], row[8]
        assert strict == (engine == "MPI+CUDA")
    for row in mm.mixed_sparse_rows():
        engine, strict = row[5], row[9]
        assert strict == (engine == "MPI+CUDA")


def test_gate_predicates():
    # Dtype leg: only f64 has a strictly narrower storage dtype.
    assert mm.mixed_capable(8)
    assert not mm.mixed_capable(4)
    # Profile leg: PCIe streaming + a real SGEMM/DGEMM gap.
    assert mm.mixed_advantage(mm.gtx280_cublas())
    assert not mm.mixed_advantage(mm.q6600_atlas())
    # Conjunction, matched against the live dispatch core.
    for ranks in mm.PAPER_RANKS:
        for gpu in (False, True):
            p = mm.params(ranks, gpu)
            assert mm.model_mixed_engaged(p, 8) == gpu
            assert not mm.model_mixed_engaged(p, 4)


def test_uncovered_methods_fall_through_to_the_uniform_twin():
    p = mm.params(16, gpu=True)
    n = mm.PAPER_N
    for m in ("bicg", "gmres", "pipecg"):
        assert mm.iter_makespan_mixed(m, n, 100, 30, p, 8) == (
            mm.iter_makespan_gpudirect(m, n, 100, 30, p, 8)
        )
        assert mm.sparse_iter_makespan_mixed(m, n, 5 * n, 100, 30, p, 8) == (
            mm.sparse_iter_makespan_gpudirect(m, n, 5 * n, 100, 30, p, 8)
        )


# ---------------------------------------------------------------------------
# 3. committed artifact
# ---------------------------------------------------------------------------


def test_mixed_artifact_bytes():
    root = pathlib.Path(__file__).resolve().parents[2]
    assert (root / "BENCH_mixed.json").read_text() == mm.render_mixed_json()


def test_mixed_artifact_is_valid_json_with_expected_schema():
    root = pathlib.Path(__file__).resolve().parents[2]
    doc = json.loads((root / "BENCH_mixed.json").read_text())
    assert doc["network"] == "gigabit_ethernet"
    assert doc["tile"] == 256
    assert doc["iters"] == mm.MIXED_ITERS
    assert doc["refine_iters"] == mm.MODEL_REFINE_ITERS
    entries, sparse = doc["entries"], doc["sparse"]
    assert len(entries) == 40 and len(sparse) == 40
    for e in entries + sparse:
        assert e["mixed_secs"] <= e["f64_secs"] * LE_SLACK
        if e["strict"]:
            assert e["engine"] == "MPI+CUDA"
            assert e["mixed_secs"] < e["f64_secs"]
        else:
            assert e["engine"] == "MPI+ATLAS"
            assert e["mixed_secs"] == e["f64_secs"]  # literal wash
        assert abs(
            e["saved_frac"] - (1.0 - e["mixed_secs"] / e["f64_secs"])
        ) <= 5e-5  # the emitted ratio is rounded to 4 decimals


# ---------------------------------------------------------------------------
# 4. model structure
# ---------------------------------------------------------------------------


def test_refined_twin_decomposes_into_its_priced_legs():
    for ranks in mm.PAPER_RANKS:
        p = mm.params(ranks, gpu=True)
        n = mm.PAPER_N
        demote = mm.demote_pass(p, mm.local_matrix_elems(n, p), 8)
        sweeps = mm.MODEL_REFINE_ITERS * (
            mm.refine_sweep(n, p) + 2.0 * mm.trsv_resident_makespan(n, p, 4)
        )
        # Same association as the twin: demote + narrow + sweeps.
        assert mm.lu_makespan_refined(n, p, 8) == (
            demote + mm.lu_makespan_gpudirect(n, p, 4) + sweeps
        )
        assert mm.chol_makespan_refined(n, p, 8) == (
            demote + mm.chol_makespan_gpudirect(n, p, 4) + sweeps
        )
        # The min() never clamps at paper scale: the narrow arm genuinely
        # wins, it is not being rescued by the baseline.
        assert demote + mm.lu_makespan_gpudirect(n, p, 4) + sweeps < (
            mm.lu_makespan_gpudirect(n, p, 8)
        )


def test_resident_substitution_drops_only_the_factor_tile_broadcast():
    for ranks in (1, 2, 4, 8, 16):
        for gpu in (False, True):
            p = mm.params(ranks, gpu)
            for n in (8_192, mm.PAPER_N):
                res = mm.trsv_resident_makespan(n, p, 4)
                full = mm.trsv_makespan(n, p, 4)
                if p.pc == 1:
                    # tree(1, t²) = 0: nothing to drop on one-column meshes.
                    assert res == full
                else:
                    assert res < full
                # The dropped leg is exactly my_rows·tree(pc, t²) per step.
                kt = mm.ceil_div(n, p.tile)
                leg = p.tree(p.pc, p.tile * p.tile, 4)
                dropped = sum(
                    mm.ceil_div(kt - k - 1, p.pr) * leg for k in range(kt)
                )
                assert abs((full - res) - dropped) <= 1e-9 * max(full, 1.0)


def test_paper_scale_cuda_speedups_clear_the_bar():
    p = mm.params(16, gpu=True)
    n = mm.PAPER_N
    pairs = (
        ("LU", mm.lu_makespan_gpudirect(n, p, 8), mm.lu_makespan_refined(n, p, 8)),
        (
            "Cholesky",
            mm.chol_makespan_gpudirect(n, p, 8),
            mm.chol_makespan_refined(n, p, 8),
        ),
        (
            "CG",
            mm.iter_makespan_gpudirect("cg", n, 100, 30, p, 8),
            mm.iter_makespan_mixed("cg", n, 100, 30, p, 8),
        ),
        (
            "BiCGSTAB",
            mm.iter_makespan_gpudirect("bicgstab", n, 100, 30, p, 8),
            mm.iter_makespan_mixed("bicgstab", n, 100, 30, p, 8),
        ),
    )
    for kernel, wide, mixed in pairs:
        assert wide / mixed > 1.5, f"{kernel}: {wide / mixed:.3f}x"


def test_sparse_mixed_win_is_the_halved_byte_stream():
    # The sparse iteration is memory/wire-bound: the narrow arm's per-iter
    # saving must be a material fraction on the accelerated arm.
    p = mm.params(16, gpu=True)
    for stencil, grid, dim in mm.HALO_STENCILS:
        n = grid ** dim
        nnz = mm.stencil_halo_counts(grid, dim, p.tile, p.pr)["total_nnz"]
        wide = mm.sparse_iter_makespan_gpudirect("cg", n, nnz, 100, 30, p, 8)
        mixed = mm.sparse_iter_makespan_mixed("cg", n, nnz, 100, 30, p, 8)
        assert mixed < wide
        assert (wide - mixed) / wide > 0.10, f"{stencil}: {(wide - mixed) / wide}"


# ---------------------------------------------------------------------------
# 5. numeric refinement simulation (numpy mirror of plu_solve_refined)
# ---------------------------------------------------------------------------


def _lu_factor(a):
    """Partial-pivot LU in a's own dtype (f32 mirrors the narrow factors)."""
    n = a.shape[0]
    lu = a.copy()
    piv = np.arange(n)
    for k in range(n):
        p = k + int(np.argmax(np.abs(lu[k:, k])))
        if p != k:
            lu[[k, p]] = lu[[p, k]]
            piv[[k, p]] = piv[[p, k]]
        lu[k + 1:, k] /= lu[k, k]
        lu[k + 1:, k + 1:] -= np.outer(lu[k + 1:, k], lu[k, k + 1:])
    return lu, piv


def _lu_solve(lu, piv, b):
    n = lu.shape[0]
    x = b[piv].astype(lu.dtype)
    for k in range(n):  # L y = Pb (unit diagonal)
        x[k + 1:] -= lu[k + 1:, k] * x[k]
    for k in range(n - 1, -1, -1):  # U x = y
        x[k] /= lu[k, k]
        x[:k] -= lu[:k, k] * x[k]
    return x


def _refined_solve(a_hi, b_hi):
    """Mirror of plu_solve_refined: f32 factors, f64 residual sweeps,
    berr = ‖r‖∞ / (‖A‖∞·‖x‖∞ + ‖b‖∞), 0.5 stagnation guard, 10 sweeps."""
    n = a_hi.shape[0]
    lu, piv = _lu_factor(a_hi.astype(np.float32))
    x = _lu_solve(lu, piv, b_hi.astype(np.float32)).astype(np.float64)
    anorm = np.abs(a_hi).sum(axis=1).max()
    bnorm = np.abs(b_hi).max()
    bound = refine_bound(n)

    def berr(r, x):
        xnorm = np.abs(x).max()
        return np.abs(r).max() / max(anorm * xnorm + bnorm, np.finfo(float).tiny)

    r = b_hi - a_hi @ x
    rnorm = np.abs(r).max()
    err = berr(r, x)
    sweeps = 0
    converged = err <= bound
    while not converged and sweeps < REFINE_MAX_SWEEPS:
        d = _lu_solve(lu, piv, r.astype(np.float32)).astype(np.float64)
        x = x + d
        sweeps += 1
        r = b_hi - a_hi @ x
        rnorm2 = np.abs(r).max()
        stagnated = rnorm2 > REFINE_STAGNATION * rnorm
        rnorm = rnorm2
        err = berr(r, x)
        converged = err <= bound
        if not converged and stagnated:
            break
    return x, sweeps, converged, err


def test_refined_simulation_meets_the_wide_bound_on_a_good_operator():
    rng = np.random.default_rng(7)
    n = 160
    a = rng.standard_normal((n, n))
    a += np.diag(np.abs(a).sum(axis=1) + 1.0)  # strictly diag-dominant
    x_true = rng.standard_normal(n)
    b = a @ x_true
    x, sweeps, converged, err = _refined_solve(a, b)
    assert converged, f"berr {err}"
    assert 1 <= sweeps <= REFINE_MAX_SWEEPS  # f32 factors need >= 1 sweep
    assert err <= refine_bound(n)
    # Forward error far beyond f32 accuracy (eps32 ~ 6e-8).
    assert np.abs(x - x_true).max() / np.abs(x_true).max() < 1e-10


def test_refined_simulation_reports_failure_on_a_hilbert_system():
    n = 24
    i, j = np.indices((n, n))
    a = 1.0 / (i + j + 1.0)  # cond ~ 10^32: hopeless for f32 factors
    b = a @ np.ones(n)
    _, sweeps, converged, _ = _refined_solve(a, b)
    assert not converged, "refinement claimed convergence on a Hilbert system"
    assert sweeps <= REFINE_MAX_SWEEPS


def test_refined_simulation_sweep_contracts_geometrically():
    # Each sweep should gain roughly -log2(u_f32) bits: after sweep s the
    # residual norm drops by orders of magnitude until it hits the floor.
    rng = np.random.default_rng(11)
    n = 96
    a = rng.standard_normal((n, n))
    a += np.diag(np.abs(a).sum(axis=1) + 1.0)
    b = a @ rng.standard_normal(n)
    lu, piv = _lu_factor(a.astype(np.float32))
    x = _lu_solve(lu, piv, b.astype(np.float32)).astype(np.float64)
    norms = [np.abs(b - a @ x).max()]
    for _ in range(3):
        d = _lu_solve(lu, piv, (b - a @ x).astype(np.float32)).astype(np.float64)
        x = x + d
        norms.append(np.abs(b - a @ x).max())
    # First sweep contracts hard (well below the 0.5 stagnation guard).
    assert norms[1] < 1e-3 * norms[0]
