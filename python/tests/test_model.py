"""L2 tile ops (model.py) vs the oracle, plus shape/flop metadata checks.

Every op the rust coordinator will call must (a) match ref.py numerically,
(b) lower with the exact static shapes the manifest advertises.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

T = 128  # tile size used for numeric checks (fast); shapes checked for all


def _tol(dtype):
    return dict(rtol=3e-4, atol=3e-4) if dtype == "f32" else dict(rtol=1e-9, atol=1e-9)


def _np_dtype(d):
    return np.float32 if d == "f32" else np.float64


def _spd(rng, t, dt):
    a = rng.standard_normal((t, t))
    a = a @ a.T + t * np.eye(t)
    return jnp.asarray(a, dtype=dt)


def _lower_tri(rng, t, dt, unit=False):
    # Damped off-diagonals keep the solve well-conditioned so f32
    # comparisons against the oracle are meaningful.
    a = np.tril(rng.standard_normal((t, t))) * 0.2
    np.fill_diagonal(a, 1.0 if unit else np.abs(a.diagonal()) + 1.0)
    return jnp.asarray(a, dtype=dt)


def _upper_tri(rng, t, dt):
    a = np.triu(rng.standard_normal((t, t))) * 0.2
    np.fill_diagonal(a, np.abs(a.diagonal()) + 1.0)
    return jnp.asarray(a, dtype=dt)


def _args_for(name, rng, t, dtype):
    """Build numerically well-posed concrete args for op `name`."""
    dt = _np_dtype(dtype)
    r = lambda shape: jnp.asarray(rng.standard_normal(shape), dtype=dt)
    if name in ("gemm",):
        return (r((t, t)), r((t, t)))
    if name in ("gemm_update", "gemm_acc"):
        return (r((t, t)), r((t, t)), r((t, t)))
    if name in ("gemv", "gemv_t"):
        return (r((t, t)), r((t,)))
    if name == "gemm_nt_update":
        return (r((t, t)), r((t, t)), r((t, t)))
    if name in ("gemv_update", "gemv_acc", "gemv_t_acc"):
        return (r((t,)), r((t, t)), r((t,)))
    if name == "potrf":
        return (_spd(rng, t, dt),)
    if name == "trsm_llu":
        return (_lower_tri(rng, t, dt, unit=True), r((t, t)))
    if name == "trsm_ru":
        return (r((t, t)), _upper_tri(rng, t, dt))
    if name == "trsm_rlt":
        return (r((t, t)), _lower_tri(rng, t, dt))
    if name == "trsv_lu":
        return (_lower_tri(rng, t, dt, unit=True), r((t,)))
    if name == "trsv_l":
        return (_lower_tri(rng, t, dt), r((t,)))
    if name == "trsv_u":
        return (_upper_tri(rng, t, dt), r((t,)))
    if name == "trsv_lt":
        return (_lower_tri(rng, t, dt), r((t,)))
    if name == "dot":
        return (r((t,)), r((t,)))
    if name == "axpy":
        return (jnp.asarray(rng.standard_normal(), dtype=dt), r((t,)), r((t,)))
    raise AssertionError(name)


_REF = {
    "gemm": ref.ref_gemm,
    "gemm_acc": ref.ref_gemm_acc,
    "gemm_update": ref.ref_gemm_update,
    "gemv": ref.ref_gemv,
    "gemv_t": lambda a, x: ref.ref_gemv(a.T, x),
    "gemv_update": ref.ref_gemv_update,
    "gemv_acc": ref.ref_gemv_acc,
    "gemv_t_acc": ref.ref_gemv_t_acc,
    "gemm_nt_update": lambda c, a, b: ref.ref_gemm_update(c, a, b.T),
    "potrf": ref.ref_potrf,
    "trsm_llu": ref.ref_trsm_llu,
    "trsm_ru": ref.ref_trsm_ru,
    "trsm_rlt": ref.ref_trsm_rlt,
    "trsv_lu": ref.ref_trsv_lu,
    "trsv_l": ref.ref_trsv_l,
    "trsv_u": ref.ref_trsv_u,
    "trsv_lt": ref.ref_trsv_lt,
    "dot": ref.ref_dot,
    "axpy": ref.ref_axpy,
}


def test_op_table_covers_ref():
    assert set(model.OPS) == set(_REF)


@pytest.mark.parametrize("dtype", model.DTYPES)
@pytest.mark.parametrize("name", sorted(model.OPS))
def test_op_matches_ref(name, dtype):
    rng = np.random.default_rng(hash((name, dtype)) % 2**31)
    args = _args_for(name, rng, T, dtype)
    builder, _, _ = model.OPS[name]
    (got,) = builder(*args)
    want = _REF[name](*args)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("name", sorted(model.OPS))
def test_trsm_ops_actually_solve(name):
    """For triangular ops verify the residual of the solved system directly."""
    if not name.startswith(("trsm", "trsv")):
        pytest.skip("not a triangular solve")
    rng = np.random.default_rng(7)
    args = _args_for(name, rng, T, "f64")
    builder, _, _ = model.OPS[name]
    (x,) = builder(*args)
    if name == "trsm_llu":
        l, b = args
        resid = l @ x - b
    elif name == "trsm_ru":
        b, u = args
        resid = x @ u - b
    elif name == "trsm_rlt":
        b, l = args
        resid = x @ l.T - b
    elif name == "trsv_lu" or name == "trsv_l":
        l, b = args
        resid = l @ x - b
    elif name == "trsv_u":
        u, y = args
        resid = u @ x - y
    elif name == "trsv_lt":
        l, y = args
        resid = l.T @ x - y
    # scaled residual: random triangular systems are only moderately
    # conditioned, so bound ||resid||_max relative to the data magnitude.
    # (unit-lower random systems can have exponentially large solutions, so
    # include ||x|| in the scale)
    scale = max(
        [float(jnp.max(jnp.abs(a))) for a in args] + [float(jnp.max(jnp.abs(x)))]
    )
    assert float(jnp.max(jnp.abs(resid))) / scale < 1e-7


def test_potrf_reconstructs():
    rng = np.random.default_rng(11)
    (a,) = _args_for("potrf", rng, T, "f64")
    builder, _, _ = model.OPS["potrf"]
    (l,) = builder(a)
    np.testing.assert_allclose(l @ l.T, a, rtol=1e-9, atol=1e-7)
    # strictly upper part must be exactly zero
    assert float(jnp.max(jnp.abs(jnp.triu(l, k=1)))) == 0.0


@pytest.mark.parametrize("name", sorted(model.OPS))
def test_example_args_shapes(name):
    """example_args must agree with the declared shape lambdas at every tile."""
    _, shapes, _ = model.OPS[name]
    for tile in model.TILES:
        for dtype in model.DTYPES:
            args = model.example_args(name, tile, dtype)
            assert len(args) == len(shapes)
            for arg, s in zip(args, shapes):
                assert arg.shape == s(tile)


def test_flop_counts_positive_and_scale():
    for name, (_b, _s, flops) in model.OPS.items():
        assert flops(128) > 0, name
        assert flops(256) > flops(128), name
    # BLAS-3 ops must scale ~t^3, BLAS-1 ~t
    assert model.OPS["gemm"][2](256) == 8 * model.OPS["gemm"][2](128)
    assert model.OPS["dot"][2](256) == 2 * model.OPS["dot"][2](128)


def test_artifact_name_format():
    assert model.artifact_name("gemm", 256, "f32") == "gemm_f32_256"
