"""No-toolchain verification of the copy-engine / prefetch PR (rust
DESIGN.md §13).

Five independent oracles:

1. **Model-twin inequalities** — exactly what `cargo bench --bench
   prefetch` asserts (`prefetch <= resident <= streaming` on every emitted
   configuration, strict wherever residency left PCIe on the compute
   path, an exact wash wherever nothing streams), over every bench row
   plus off-bench sweeps: tiny device budgets (thrash), host profiles
   (hidden must be 0), odd meshes and dtypes.
2. **Committed artifact** — `BENCH_prefetch.json` (and the regenerated
   `BENCH_residency.json`) must be byte-identical to what the model
   produces.
3. **Three-timeline clock property** — a transcription of
   `comm/clock.rs::VClock` with the copy-engine timeline, replayed on
   random traces: `max(compute, NIC, PCIe) <= makespan <= their sum`, and
   the async replay never loses to the blocking one.
4. **Async Ctx accounting** — a transcription of `pblas::Ctx`'s
   copy-engine path over the TileCache replayed against the synchronous
   residency accounting on random op traces: bytes charged are identical
   (only *when* changes), the compute-timeline transfer share never grows,
   and the async makespan never exceeds the synchronous one.
5. **Solver-rewrite bit-identity** — the GMRES and BiCG fused sequences
   (the satellite rewrites) next to their unfused forms, and the
   `gemv_acc` / `gemv_t_acc` accumulation next to the former
   gemv-into-scratch + axpy pairs, all bit for bit in float64.
"""

import dataclasses
import pathlib

import numpy as np
import pytest

import model_mirror as mm
from test_residency_sim import TileCache, _dot4, _random_trace

LE_SLACK = 1.0 + 1e-9


# ---------------------------------------------------------------------------
# 1 + 2. model twins — the bench acceptance shape and the committed artifact
# ---------------------------------------------------------------------------


def test_prefetch_bench_acceptance_shape():
    rows = mm.prefetch_rows()
    assert len(rows) == len(mm.PAPER_RANKS) * (2 * 6 + 2)
    for kernel, engine, n, ranks, streaming, resident, prefetch, strict in rows:
        assert prefetch <= resident * LE_SLACK, (
            f"{kernel} {engine} P={ranks}: prefetch {prefetch} > resident {resident}"
        )
        assert resident <= streaming * LE_SLACK, (
            f"{kernel} {engine} P={ranks}: resident {resident} > streaming {streaming}"
        )
        if strict:
            assert prefetch < resident, (
                f"{kernel} {engine} P={ranks}: the copy engine must strictly win"
            )
        else:
            # Nothing streams (host arm / sparse) or the comm lookahead
            # already hid the PCIe: prefetch must be an exact wash, not a
            # fabricated win.
            assert prefetch == pytest.approx(resident, rel=1e-12), (
                f"{kernel} {engine} P={ranks}: must be a wash"
            )


def test_lu_strictness_follows_the_headroom_predicate():
    # The LU rows are strict exactly where the predicate says residency
    # left PCIe on the critical path — and the predicate must agree with
    # the twins' actual outcome on every configuration.
    for ranks in mm.PAPER_RANKS:
        p = mm.params(ranks, True)
        headroom = mm.lu_prefetch_headroom(mm.PAPER_N, p, 4)
        r = mm.lu_makespan_resident(mm.PAPER_N, p, 4)
        pf = mm.lu_makespan_prefetch(mm.PAPER_N, p, 4)
        if headroom:
            assert pf < r, f"P={ranks}: headroom promised a strict win"
        else:
            assert pf == r, f"P={ranks}: no headroom, must be an exact wash"


def test_committed_prefetch_artifact_matches_the_mirror():
    root = pathlib.Path(__file__).resolve().parents[2]
    assert (root / "BENCH_prefetch.json").read_text() == mm.render_prefetch_json()


def test_twins_hold_beyond_bench_configs():
    # Sweep shapes/sizes/dtypes the bench doesn't cover, incl. tiny n and
    # non-square meshes: the prefetch <= resident <= streaming chain must
    # be structural, not tuned.
    for ranks in (1, 2, 3, 6, 8, 12, 16):
        for gpu in (False, True):
            for b in (4, 8):
                for n in (256, 512, 4_096, 30_000):
                    p = mm.params(ranks, gpu)
                    assert mm.lu_makespan_prefetch(n, p, b) <= (
                        mm.lu_makespan_resident(n, p, b) * LE_SLACK
                    ), (ranks, gpu, b, n)
                    assert mm.chol_makespan_prefetch(n, p, b) <= (
                        mm.chol_makespan_resident(n, p, b) * LE_SLACK
                    ), (ranks, gpu, b, n)
                    for ov in (False, True):
                        assert mm.summa_makespan_prefetch(n, p, b, ov) <= (
                            mm.summa_makespan_resident(n, p, b, ov) * LE_SLACK
                        ), (ranks, gpu, b, n, ov)
                    for m in ("cg", "pipecg", "bicgstab"):
                        for iters in (0, 1, 37):
                            pf = mm.iter_makespan_prefetch(m, n, iters, 30, p, b)
                            rs = mm.iter_makespan_fused(m, n, iters, 30, p, b)
                            st = mm.iter_makespan(m, n, iters, 30, p, b)
                            assert pf <= rs * LE_SLACK, (ranks, gpu, b, n, m, iters)
                            assert rs <= st * LE_SLACK, (ranks, gpu, b, n, m, iters)


def test_tiny_budgets_thrash_but_prefetch_still_hides_the_restreams():
    # Budgets far below the working set: residency degenerates to the
    # paper's per-call streaming (nothing stays resident), but the depth-1
    # prefetch still pipelines those re-streams under compute — the
    # "budget forced eviction" case the live pgemv targets.
    for budget in (4096, 1 << 20, 64 << 20):
        for ranks in (1, 4, 16):
            p = dataclasses.replace(mm.params(ranks, True), device_mem=budget)
            n = 30_000
            for m in ("cg", "pipecg", "bicgstab"):
                pf = mm.iter_makespan_prefetch(m, n, 100, 30, p, 4)
                rs = mm.iter_makespan_fused(m, n, 100, 30, p, 4)
                st = mm.iter_makespan(m, n, 100, 30, p, 4)
                assert pf <= rs * LE_SLACK <= st * LE_SLACK**2, (budget, ranks, m)
                assert pf < rs, f"thrash is where hiding matters: {budget} {ranks} {m}"
            # Direct methods under thrash budgets too.
            assert mm.lu_makespan_prefetch(n, p, 4) <= (
                mm.lu_makespan_resident(n, p, 4) * LE_SLACK
            )
            assert mm.summa_makespan_prefetch(n, p, 4, True) <= (
                mm.summa_makespan_resident(n, p, 4, True) * LE_SLACK
            )


def test_host_profiles_hide_nothing():
    # pcie_bw == 0: the copy engine has nothing to carry — every prefetch
    # twin must equal its synchronous counterpart *exactly* (the live
    # assert is pcie_hidden_secs == 0 on host profiles).
    for ranks in (1, 3, 8):
        p = mm.params(ranks, False)
        n = 8_192
        assert mm.lu_makespan_prefetch(n, p, 4) == mm.lu_makespan_resident(n, p, 4)
        assert mm.chol_makespan_prefetch(n, p, 4) == mm.chol_makespan_resident(n, p, 4)
        assert mm.summa_makespan_prefetch(n, p, 4, True) == (
            mm.summa_makespan_resident(n, p, 4, True)
        )
        for m in ("cg", "pipecg", "bicgstab"):
            assert mm.iter_makespan_prefetch(m, n, 100, 30, p, 8) == (
                mm.iter_makespan_fused(m, n, 100, 30, p, 8)
            )


# ---------------------------------------------------------------------------
# 3. three-timeline clock property (comm/clock.rs transcription)
# ---------------------------------------------------------------------------


class VClock:
    """Transcription of comm/clock.rs::VClock with the copy-engine timeline."""

    def __init__(self):
        self.now = 0.0
        self.nic_free = 0.0
        self.pcie_free = 0.0
        self.compute = 0.0
        self.comm_wait = 0.0
        self.xfer = 0.0

    def busy_until(self):
        return max(self.now, self.nic_free, self.pcie_free)

    def advance_compute(self, dt):
        self.now += dt
        self.compute += dt

    def advance_transfer(self, dt):
        self.now += dt
        self.xfer += dt

    def nic_occupy(self, dt):
        start = max(self.nic_free, self.now)
        self.nic_free = start + dt
        return self.nic_free

    def advance_send(self, dt):
        end = self.nic_occupy(dt)
        self.observe_arrival(end)

    def observe_arrival(self, arrival):
        if arrival > self.now:
            self.comm_wait += arrival - self.now
            self.now = arrival

    def pcie_occupy(self, dt):
        start = max(self.pcie_free, self.now)
        self.pcie_free = start + dt
        return self.pcie_free

    def pcie_wait(self, ready):
        if ready > self.now:
            self.xfer += ready - self.now
            self.now = ready


@pytest.mark.parametrize("seed", range(32))
def test_three_timeline_clock_property(seed):
    # rust clock.rs::overlap_never_loses_and_is_bounded_on_three_timelines:
    # identical random trace through a blocking clock (sends + transfers on
    # the compute timeline) and an overlapped one (NIC + copy engine).
    rng = np.random.default_rng(seed)
    blocking, overlapped = VClock(), VClock()
    total_compute = total_send = total_xfer = total_comm_blocking = 0.0
    pending = []
    for _ in range(1 + int(rng.integers(40))):
        kind = int(rng.integers(5))
        if kind == 0:
            dt = rng.random() * 2.0
            blocking.advance_compute(dt)
            overlapped.advance_compute(dt)
            total_compute += dt
        elif kind == 1:
            dt = rng.random()
            blocking.advance_send(dt)
            overlapped.nic_occupy(dt)
            total_send += dt
            total_comm_blocking += dt
        elif kind == 2:
            dt = rng.random() * 0.5
            blocking.advance_transfer(dt)
            pending.append(overlapped.pcie_occupy(dt))
            total_xfer += dt
        elif kind == 3:
            if pending:
                overlapped.pcie_wait(pending.pop())
        else:
            arr = rng.random() * 10.0
            total_comm_blocking += max(arr - blocking.now, 0.0)
            blocking.observe_arrival(arr)
            overlapped.observe_arrival(arr)
    for ready in pending:
        overlapped.pcie_wait(ready)
    ms_over, ms_block = overlapped.busy_until(), blocking.busy_until()
    eps = 1e-12
    assert max(total_compute, total_send, total_xfer) <= ms_over + eps
    assert ms_over <= total_compute + total_comm_blocking + total_xfer + eps
    assert ms_over <= ms_block + eps, "overlap must never lose"
    assert overlapped.compute == pytest.approx(total_compute)
    assert overlapped.xfer <= blocking.xfer + eps, "waits charge only the remainder"


# ---------------------------------------------------------------------------
# 4. async Ctx accounting vs the synchronous residency accounting
# ---------------------------------------------------------------------------

PCIE_BW = 5.5e9
COMPUTE_DT = 2e-5


class PinnedTileCache(TileCache):
    """Transcription of the rust TileCache with in-flight pinning
    (DESIGN.md §13): make_room never evicts a pinned entry, and admission
    declines (the buffer streams per call) when only pinned entries could
    make room."""

    def __init__(self, budget):
        super().__init__(budget)
        self.pinned = set()

    def _make_room(self, extra):
        while self.used + extra > self.budget:
            victims = [k for k in self.map if k not in self.pinned]
            if not victims:
                return  # admission declines
            victim = min(victims, key=lambda k: self.map[k][2])
            self.used -= self.map.pop(victim)[0]

    def _touch_read(self, key, nbytes):
        tick = self._next_tick()
        if key in self.map:
            self.map[key][2] = tick
            return 0
        if nbytes > self.budget:
            return nbytes
        self._make_room(nbytes)
        if self.used + nbytes <= self.budget:
            self.map[key] = [nbytes, False, tick]
            self.used += nbytes
        return nbytes

    def _touch_write(self, key, nbytes):
        tick = self._next_tick()
        if key in self.map:
            e = self.map[key]
            e[2] = tick
            if e[1]:
                return 0
            e[1] = True
            return nbytes
        if nbytes <= self.budget:
            self._make_room(nbytes)
            if self.used + nbytes <= self.budget:
                self.map[key] = [nbytes, True, tick]
                self.used += nbytes
        return nbytes

    def host_mut(self, key):
        self.pinned.discard(key)
        super().host_mut(key)


def _replay_flows(trace, budget):
    """Replay one op/host_read/host_mut trace through (a) the synchronous
    residency accounting (PR 4's charge_op) and (b) the copy-engine path
    (depth-1 prefetch of the next op's read set with pinning + async
    write-back), each over its own cache — a transcription of pblas::Ctx.
    Returns the two clocks and the per-flow total bytes that crossed the
    link."""
    sync_clock, async_clock = VClock(), VClock()
    sync_cache, async_cache = TileCache(budget), PinnedTileCache(budget)
    inflight, flushes = {}, {}
    sync_bytes = async_bytes = 0
    hidden = hits = 0.0

    ops = [ev for ev in trace if ev[0] == "op"]
    op_idx = -1
    for ev in trace:
        kind, a, c = ev
        if kind == "op":
            op_idx += 1
            ins, out = a, c
            # --- synchronous flow: everything on the compute timeline.
            h2d, d2h, _full = sync_cache.access(ins, out)
            sync_clock.advance_transfer(h2d / PCIE_BW)
            sync_clock.advance_compute(COMPUTE_DT)
            sync_clock.advance_transfer(d2h / PCIE_BW)
            sync_bytes += h2d + d2h
            # --- async flow: prefetch the *next* op's read set first
            # (depth-1, as the live loops do; admitted entries are pinned),
            # then serve this op.
            nxt = ops[op_idx + 1] if op_idx + 1 < len(ops) else None
            if nxt is not None:
                for key, nbytes in nxt[1]:
                    if key in async_cache.map:
                        continue
                    got = async_cache._touch_read(key, nbytes)
                    if got and key in async_cache.map:  # admitted, not declined
                        dt = got / PCIE_BW
                        inflight[key] = (async_clock.pcie_occupy(dt), dt)
                        async_cache.pinned.add(key)
                        hidden += dt
                        async_bytes += got
            for key, nbytes in ins:
                got = async_cache._touch_read(key, nbytes)
                if got == 0:
                    if key in inflight:
                        ready, _dt = inflight.pop(key)
                        async_cache.pinned.discard(key)
                        hits += 1
                        hidden -= max(ready - async_clock.now, 0.0)
                        async_clock.pcie_wait(ready)
                else:
                    if key in inflight:  # defensive: pinning prevents this
                        _ready, dt = inflight.pop(key)
                        async_cache.pinned.discard(key)
                        hidden -= dt
                    async_clock.advance_transfer(got / PCIE_BW)
                    async_bytes += got
            async_clock.advance_compute(COMPUTE_DT)
            if out is not None:
                key, nbytes = out
                got = async_cache._touch_write(key, nbytes)
                if got:
                    # Always async: the flush ledger lives on the Ctx, so
                    # declined/oversized buffers queue on the copy engine
                    # too.
                    async_bytes += got
                    dt = got / PCIE_BW
                    flushes[key] = async_clock.pcie_occupy(dt)
                    hidden += dt
        elif kind == "host_read":
            sync_cache.host_read(a)
            if a in flushes:
                ready = flushes.pop(a)
                hidden -= max(ready - async_clock.now, 0.0)
                async_clock.pcie_wait(ready)
            async_cache.host_read(a)
        else:
            sync_cache.host_mut(a)
            if a in inflight:  # abandoned: revoke the whole credit
                _ready, dt = inflight.pop(a)
                hidden -= dt
            flushes.pop(a, None)
            async_cache.host_mut(a)
    return sync_clock, async_clock, sync_bytes, async_bytes, hidden, hits


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("budget", [1536, 4096, 64 * 512, 1 << 20])
def test_async_accounting_never_loses_and_moves_no_extra_bytes(seed, budget):
    # budget 1536 = three 512-byte entries, i.e. about one op's operand
    # set: the pathological case where an unpinned prefetch would evict
    # the imminent op's operands — pinning makes admission decline
    # instead, so the copy engine degrades gracefully.
    rng = np.random.default_rng(300 + seed)
    trace = _random_trace(rng)
    sync_c, async_c, sync_b, async_b, hidden, hits = _replay_flows(trace, budget)
    eps = 1e-12
    # The copy engine re-times transfers; it must not lose makespan...
    assert async_c.busy_until() <= sync_c.busy_until() + eps, (seed, budget)
    # ...the compute-timeline transfer share can only shrink...
    assert async_c.xfer <= sync_c.xfer + eps, (seed, budget)
    # ...compute attribution is untouched...
    assert async_c.compute == pytest.approx(sync_c.compute)
    # ...and the copy engine can only *add* wasted DMA (a prefetched
    # buffer invalidated or evicted before use), never elide demand bytes
    # the synchronous flow would have moved.
    assert async_b >= sync_b, (seed, budget)
    if budget >= 1 << 20:
        assert hits > 0, "a warm trace must serve some operands from prefetch"
    assert hidden >= -eps, "revocations can never exceed the credit"


@pytest.mark.parametrize("seed", range(4))
def test_async_accounting_moves_identical_bytes_without_host_mutation(seed):
    # On an op/host_read-only trace (reads never invalidate) of
    # read-modify-write ops — every live charge_op site passes its output
    # in the read set too, exactly like the engine ops' operand tables —
    # the async flow moves byte-for-byte what the synchronous flow moves:
    # prefetch changes *when* bytes cross, never whether.  (A write-only
    # output would make a prefetched read copy dead weight; no hot path
    # has one since the gemv_acc rewrite.)
    rng = np.random.default_rng(600 + seed)
    trace = []
    for ev in _random_trace(rng):
        if ev[0] == "host_mut":
            continue
        if ev[0] == "op" and ev[2] is not None and ev[2] not in ev[1]:
            trace.append(("op", ev[1] + [ev[2]], ev[2]))
        else:
            trace.append(ev)
    _sync_c, _async_c, sync_b, async_b, _hidden, _hits = _replay_flows(trace, 1 << 20)
    assert async_b == sync_b, seed


# ---------------------------------------------------------------------------
# 5. solver-rewrite bit-identity (GMRES / BiCG fused forms, gemv_acc)
# ---------------------------------------------------------------------------


def _bicg(a, b, iters, fused):
    """Serial BiCG over numpy float64, unfused vs fused update sequences —
    mirrors solvers/iterative/bicg.rs before/after the rewrite."""
    n = len(b)
    x = np.zeros(n)
    r = b.copy()
    rt = b.copy()
    p = r.copy()
    pt = rt.copy()
    rho = _dot4(rt, r)
    for _ in range(iters):
        ap = a @ p
        atpt = a.T @ pt
        ptap = _dot4(pt, ap)
        alpha = rho / ptap
        x = x + alpha * p
        if fused:
            # Shadow residual first (independent), then the fused
            # axpy+norm2+dot kernel's exact operation order.
            rt = rt + (-alpha) * atpt
            r = r + (-alpha) * ap
            rr = _dot4(r, r)
            rho_new = _dot4(rt, r)
        else:
            r = r + (-alpha) * ap
            rt = rt + (-alpha) * atpt
            rr = _dot4(r, r)
            rho_new = _dot4(rt, r)
        del rr
        beta = rho_new / rho
        rho = rho_new
        if fused:
            p = r + beta * p  # xpay
            pt = rt + beta * pt
        else:
            p = p * beta
            p = p + 1.0 * r
            pt = pt * beta
            pt = pt + 1.0 * rt
    return x, r, rt, p, pt


def test_bicg_iterates_bit_identical_fused_vs_unfused():
    rng = np.random.default_rng(17)
    n = 48
    a = rng.standard_normal((n, n)) + n * np.eye(n)
    b = rng.standard_normal(n)
    unfused = _bicg(a, b, 25, fused=False)
    fused = _bicg(a, b, 25, fused=True)
    for u, f in zip(unfused, fused):
        assert u.tobytes() == f.tobytes()


def _gmres_arnoldi(a, q0, steps, fused):
    """One Arnoldi sweep (the GMRES inner loop), uniform-loop (unfused) vs
    peeled-last-step + fused axpy/norm2 (the rewrite).  Returns (H, basis)."""
    basis = [q0.copy()]
    cols = []
    for k in range(steps):
        w = a @ basis[k]
        h = []
        if fused:
            for v in basis[:k]:
                hij = _dot4(v, w)
                w = w + (-hij) * v
                h.append(hij)
            hkk = _dot4(basis[k], w)
            w = w + (-hkk) * basis[k]  # fused kernel: same axpy...
            wnorm = np.sqrt(_dot4(w, w))  # ...then the same dot
            h.append(hkk)
        else:
            for v in basis:
                hij = _dot4(v, w)
                w = w + (-hij) * v
                h.append(hij)
            wnorm = np.sqrt(_dot4(w, w))
        h.append(wnorm)
        cols.append(h)
        basis.append(w / wnorm)
    return cols, basis


def test_gmres_arnoldi_bit_identical_fused_vs_unfused():
    rng = np.random.default_rng(23)
    n = 40
    a = rng.standard_normal((n, n))
    q0 = rng.standard_normal(n)
    q0 = q0 / np.sqrt(_dot4(q0, q0))
    cu, bu = _gmres_arnoldi(a, q0, 8, fused=False)
    cf, bf = _gmres_arnoldi(a, q0, 8, fused=True)
    for hu, hf in zip(cu, cf):
        assert np.array(hu).tobytes() == np.array(hf).tobytes()
    for vu, vf in zip(bu, bf):
        assert vu.tobytes() == vf.tobytes()


def test_gemv_acc_bit_identical_to_scratch_plus_axpy():
    # linalg::gemv_add / gemv_t_add vs the former gemv-into-scratch +
    # host-axpy pairs: same row-dot accumulation (4-wide unrolled), one
    # final add per element — bit-identical by construction.
    rng = np.random.default_rng(29)
    m = n = 24
    a = rng.standard_normal((m, n))
    x = rng.standard_normal(n)
    y0 = rng.standard_normal(m)
    # y += A x
    tmp = np.array([_dot4(a[i], x) for i in range(m)])
    want = y0 + 1.0 * tmp
    got = y0.copy()
    for i in range(m):
        got[i] += _dot4(a[i], x)
    assert got.tobytes() == want.tobytes()
    # w += A^T x: the column sums finish in scratch (same i-outer
    # accumulation order as gemv_t), then one add — NOT an in-place
    # accumulation, which would re-associate the sums.
    w0 = rng.standard_normal(n)
    tmp = np.zeros(n)
    for i in range(m):
        tmp = tmp + a[i] * x[i]
    want = w0 + 1.0 * tmp
    got = w0.copy()
    acc = np.zeros(n)
    for i in range(m):
        acc = acc + a[i] * x[i]
    got = got + acc
    assert got.tobytes() == want.tobytes()
