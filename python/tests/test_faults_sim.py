"""No-toolchain verification of the fault-tolerance PR (rust DESIGN.md §18).

Five independent oracles:

1. **Model-twin shape** — exactly what `cargo bench --bench faults`
   asserts: on every emitted grid point the fault-free checkpointed
   makespan is the base **plus exactly the priced D2H legs** (bitwise:
   the twin IS literally `base + legs`), every crash lands at or past
   the first checkpoint, and checkpointed recovery strictly undercuts
   recompute-from-scratch.  On the host arm the legs are literally zero
   (no PCIe), so the checkpointed makespan IS the base and the win is a
   pure replay-span shrink.
2. **Committed artifact** — `BENCH_faults.json` must be byte-identical
   to what the model mirror produces, with a valid schema and the
   inequalities re-checked straight from the parsed JSON.
3. **Model structure** — the checkpoint/snapshot counters, the
   restore-leg pricing (CG/BiCGSTAB snapshot 3 vectors, GMRES 1; GMRES
   ignores the policy period in favour of its restart cycle), and the
   crash-at-a-checkpoint limit where checkpointed recovery replays
   exactly zero panels.
4. **Numeric recovery simulation** — numpy mirrors of the rust
   `rust/tests/faults.rs` bit-identity tests: a panel-checkpointed LU
   and a snapshot-restarted CG that crash mid-run and recover to
   **bit-identical** results, a crash with no checkpoint that fails
   loudly (message contains "crash"), and a non-finite recurrence guard
   that reports a diagnostic instead of iterating on NaNs.
5. **Retry pricing arithmetic** — the transport's exponential-backoff
   charge for scripted drops: `times` drops of one message cost exactly
   `sum(timeout * 2^i)` seconds of waiting, mirrored against the
   `drop:0-1#2x2; timeout:1e-3` integration test's 3 ms timeline.
"""

import json
import pathlib

import numpy as np

import model_mirror as mm

RETRY_TIMEOUT = 1e-3  # comm/faults.rs FaultPlan::default().retry_timeout


# ---------------------------------------------------------------------------
# 1. model twins — bench acceptance shape
# ---------------------------------------------------------------------------


def test_faults_bench_acceptance_shape():
    rows = mm.faults_rows()
    # ranks x engines x 4 kernels x 3 crash fractions
    assert len(rows) == len(mm.PAPER_RANKS) * 2 * 4 * 3
    for (kernel, engine, n, ranks, pr, pc, every, crash, base, ckpt, legs,
         full_rec, ckpt_rec, strict) in rows:
        label = f"{kernel} {engine} P={ranks} crash={crash}"
        assert n == mm.PAPER_N and pr * pc == ranks
        # Bitwise: the ckpt twin is constructed as base + legs, nothing else.
        assert ckpt == base + legs, label
        assert strict and crash >= every, label
        assert ckpt_rec < full_rec, label


def test_host_arm_checkpoints_are_free_and_still_win():
    for row in mm.faults_rows():
        engine, base, ckpt, legs = row[1], row[8], row[9], row[10]
        if engine == "MPI+ATLAS":
            # No PCIe: the D2H leg prices to literal zero...
            assert legs == 0.0
            assert ckpt == base
        else:
            # ...while the CUDA arm pays a real, strictly positive tax.
            assert legs > 0.0
            assert ckpt > base


def test_savings_grow_with_the_crash_point():
    # Later crashes replay more under full recovery but the same bounded
    # tail under checkpointing, so the saved fraction must be monotone in
    # the crash point within each (kernel, engine, ranks) cell.
    cells = {}
    for row in mm.faults_rows():
        kernel, engine, ranks, crash = row[0], row[1], row[3], row[7]
        full_rec, ckpt_rec = row[11], row[12]
        cells.setdefault((kernel, engine, ranks), []).append(
            (crash, 1.0 - ckpt_rec / full_rec)
        )
    for key, pts in cells.items():
        pts.sort()
        saved = [s for _, s in pts]
        assert saved == sorted(saved), f"{key}: {saved}"


# ---------------------------------------------------------------------------
# 2. committed artifact
# ---------------------------------------------------------------------------


def test_faults_artifact_bytes():
    root = pathlib.Path(__file__).resolve().parents[2]
    assert (root / "BENCH_faults.json").read_text() == mm.render_faults_json()


def test_faults_artifact_is_valid_json_with_expected_schema():
    root = pathlib.Path(__file__).resolve().parents[2]
    doc = json.loads((root / "BENCH_faults.json").read_text())
    assert doc["network"] == "gigabit_ethernet"
    assert doc["tile"] == 256
    assert doc["n"] == mm.PAPER_N
    assert doc["iters"] == mm.FAULTS_ITERS
    assert doc["every_direct"] == mm.FAULTS_EVERY_DIRECT
    assert doc["every_krylov"] == mm.FAULTS_EVERY_KRYLOV
    assert doc["reboot_secs"] == mm.FAULTS_REBOOT
    entries = doc["entries"]
    assert len(entries) == 120
    kernels = {e["kernel"] for e in entries}
    assert kernels == {"LU", "Cholesky", "CG", "BiCGSTAB"}
    for e in entries:
        assert e["strict"] is True
        assert e["crash"] >= e["every"]
        assert e["ckpt_recovery_secs"] < e["full_recovery_secs"]
        assert abs(
            e["ckpt_secs"] - (e["base_secs"] + e["legs_secs"])
        ) <= 1e-6 * e["ckpt_secs"]  # 6-sig-digit serialization of an exact sum
        assert abs(
            e["saved_frac"]
            - (1.0 - e["ckpt_recovery_secs"] / e["full_recovery_secs"])
        ) <= 5e-5  # the emitted ratio is rounded to 4 decimals


# ---------------------------------------------------------------------------
# 3. model structure
# ---------------------------------------------------------------------------


def test_checkpoint_counter_includes_panel_zero():
    # One checkpoint per `every` panels, the panel-0 snapshot included, so
    # any detectable crash (probes run at boundaries > 0) has a restore
    # point at or before it.
    assert mm.n_checkpoints(235, 16) == 15
    assert mm.n_checkpoints(16, 16) == 1
    assert mm.n_checkpoints(17, 16) == 2
    assert mm.n_checkpoints(100, 10) == 10
    # Degenerate policies clamp to every-panel checkpointing.
    assert mm.n_checkpoints(8, 0) == 8


def test_direct_ckpt_leg_is_the_local_tile_share():
    for ranks in mm.PAPER_RANKS:
        p = mm.params(ranks, gpu=True)
        expect = p.xfer(mm.local_matrix_elems(mm.PAPER_N, p), 4)
        assert mm.ckpt_leg(mm.PAPER_N, p, 4) == expect
        assert expect > 0.0
        # Host profile: no PCIe link to price.
        assert mm.ckpt_leg(mm.PAPER_N, mm.params(ranks, gpu=False), 4) == 0.0


def test_krylov_snapshot_legs_and_periods():
    p = mm.params(4, gpu=True)
    n = mm.PAPER_N
    # CG and BiCGSTAB snapshot (x, r, p): exactly 3x the GMRES x-only leg.
    assert mm.krylov_snap_leg("cg", n, p, 4) == 3 * mm.krylov_snap_leg(
        "gmres", n, p, 4
    )
    assert mm.krylov_snap_leg("bicgstab", n, p, 4) == mm.krylov_snap_leg(
        "cg", n, p, 4
    )
    # Methods without a fault-tolerant variant snapshot nothing.
    assert mm.krylov_snap_leg("pipecg", n, p, 4) == 0.0
    # GMRES snapshots at restart-cycle boundaries, ignoring the policy.
    assert mm.krylov_snap_period("gmres", 10, 30) == 30
    assert mm.krylov_snap_period("cg", 10, 30) == 10
    assert mm.krylov_snap_period("cg", 0, 30) == 1


def test_crash_at_a_checkpoint_replays_zero_panels():
    # When the crash lands exactly on a checkpoint boundary the ckpt arm
    # replays nothing: recovery is the taxed run + reboot + one restore leg.
    p = mm.params(8, gpu=True)
    n, every, reboot = mm.PAPER_N, 16, mm.FAULTS_REBOOT
    crash = 3 * every
    assert mm.lu_recovery_ckpt(n, every, crash, reboot, p, 4) == (
        mm.lu_makespan_ckpt(n, every, p, 4) + reboot + mm.ckpt_leg(n, p, 4)
    )
    # The full arm replays all 48 panels and must pay strictly more.
    assert mm.lu_span(n, p, 4, 0, crash) > 0.0
    assert mm.lu_recovery_full(n, crash, reboot, p, 4) > (
        mm.lu_recovery_ckpt(n, every, crash, reboot, p, 4)
    )


def test_recovery_twins_decompose_into_their_priced_legs():
    p = mm.params(16, gpu=True)
    n, every, reboot = mm.PAPER_N, 16, mm.FAULTS_REBOOT
    crash = 117  # mid-run, not on a boundary
    last = (crash // every) * every
    # Same association as the twins: taxed run + reboot + restore + replay.
    assert mm.chol_recovery_ckpt(n, every, crash, reboot, p, 4) == (
        mm.chol_makespan_ckpt(n, every, p, 4)
        + reboot
        + mm.ckpt_leg(n, p, 4)
        + mm.chol_span(n, p, 4, last, crash)
    )
    period = mm.krylov_snap_period("cg", 10, 30)
    it_crash, it_last = 57, 50
    assert mm.iter_recovery_ckpt("cg", n, 100, 30, 10, it_crash, reboot, p, 4) == (
        mm.iter_makespan_ckpt("cg", n, 100, 30, 10, p, 4)
        + reboot
        + mm.krylov_snap_leg("cg", n, p, 4)
        + mm.iter_makespan_gpudirect("cg", n, it_crash - it_last, 30, p, 4)
    )
    assert (it_crash // period) * period == it_last


# ---------------------------------------------------------------------------
# 4. numeric recovery simulation (numpy mirrors of rust/tests/faults.rs)
# ---------------------------------------------------------------------------


def _lu_panel_step(a, k0, bs):
    """One right-looking panel of an unpivoted LU (diag-dominant input)."""
    n = a.shape[0]
    for k in range(k0, min(k0 + bs, n)):
        a[k + 1:, k] /= a[k, k]
        a[k + 1:, k + 1:] -= np.outer(a[k + 1:, k], a[k, k + 1:])


def _ckpt_lu(a0, bs, every=None, crash_panel=None):
    """Panel-checkpointed LU mirroring plu_solve_panel_ckpt: snapshot every
    `every` panels (panel 0 included), on a crash restore the last snapshot
    and replay.  `every=None` disables checkpointing — a crash then raises
    the same diagnostic shape the rust solver returns."""
    a = a0.copy()
    n = a.shape[0]
    panels = list(range(0, n, bs))
    snap = None
    idx = 0
    crashed = False
    while idx < len(panels):
        if every is not None and idx % every == 0:
            snap = (a.copy(), idx)
        if crash_panel is not None and not crashed and idx == crash_panel:
            crashed = True
            if snap is None:
                raise RuntimeError(
                    f"rank crash detected at panel {idx} with no checkpoint"
                )
            a, idx = snap[0].copy(), snap[1]
            continue
        _lu_panel_step(a, panels[idx], bs)
        idx += 1
    return a


def _diag_dominant(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    a += np.diag(np.abs(a).sum(axis=1) + 1.0)
    return a


def test_checkpointed_lu_crash_recovery_is_bit_identical():
    a0 = _diag_dominant(96, seed=3)
    plain = _ckpt_lu(a0, bs=8)
    ckpt = _ckpt_lu(a0, bs=8, every=4)
    # The checkpoint taxes time, never bits.
    assert ckpt.tobytes() == plain.tobytes()
    # Crash mid-factorization (panel 7, last snapshot at 4): restore and
    # replay reproduce the fault-free factors exactly.
    crashed = _ckpt_lu(a0, bs=8, every=4, crash_panel=7)
    assert crashed.tobytes() == plain.tobytes()


def test_crash_without_checkpoints_fails_loudly():
    a0 = _diag_dominant(64, seed=5)
    try:
        _ckpt_lu(a0, bs=8, every=None, crash_panel=5)
    except RuntimeError as e:
        assert "crash" in str(e)
    else:
        raise AssertionError("crash with no checkpoint must not succeed")


def _cg(a, b, iters, every=None, crash_iter=None):
    """Snapshot-restarted CG mirroring cg_ft: snapshot (x, r, p) every
    `every` iterations (iteration 0 included), restore + replay on crash."""
    x = np.zeros_like(b)
    r = b - a @ x
    p = r.copy()
    rs = float(r @ r)
    snap = None
    it = 0
    crashed = False
    while it < iters:
        if every is not None and it % every == 0:
            snap = (x.copy(), r.copy(), p.copy(), rs, it)
        if crash_iter is not None and not crashed and it == crash_iter:
            crashed = True
            x, r, p, rs, it = (
                snap[0].copy(), snap[1].copy(), snap[2].copy(), snap[3], snap[4],
            )
            continue
        ap = a @ p
        with np.errstate(divide="ignore", invalid="ignore"):
            alpha = np.float64(rs) / np.float64(p @ ap)
        if not np.isfinite(alpha):
            raise RuntimeError(f"cg: non-finite recurrence at iteration {it}")
        x = x + alpha * p
        r = r - alpha * ap
        rs2 = float(r @ r)
        beta = rs2 / rs
        p = r + beta * p
        rs = rs2
        it += 1
    return x


def test_snapshot_restarted_cg_is_bit_identical():
    n = 80
    a = _diag_dominant(n, seed=9)
    a = (a + a.T) / 2.0 + n * np.eye(n)  # SPD
    b = np.random.default_rng(13).standard_normal(n)
    plain = _cg(a, b, iters=30)
    snapped = _cg(a, b, iters=30, every=10)
    assert snapped.tobytes() == plain.tobytes()
    # Crash at iteration 17 (last snapshot at 10): replay matches exactly.
    crashed = _cg(a, b, iters=30, every=10, crash_iter=17)
    assert crashed.tobytes() == plain.tobytes()
    # And the answer is actually a solve, not a fixed point of the harness.
    assert np.abs(a @ plain - b).max() / np.abs(b).max() < 1e-8


def test_nonfinite_recurrence_guard_reports_a_diagnostic():
    # A zero operator drives p' A p to 0 -> alpha = inf: the guard must
    # surface a diagnostic error instead of iterating on garbage.
    n = 16
    a = np.zeros((n, n))
    b = np.ones(n)
    try:
        _cg(a, b, iters=5)
    except RuntimeError as e:
        assert "non-finite" in str(e)
    else:
        raise AssertionError("CG iterated on a non-finite recurrence")


# ---------------------------------------------------------------------------
# 5. retry pricing arithmetic
# ---------------------------------------------------------------------------


def _retry_wait(times, timeout):
    """transport.rs exponential backoff: the i-th re-send of a dropped
    message waits timeout * 2^i before going out again."""
    return sum(timeout * 2.0 ** i for i in range(times))


def test_scripted_drop_backoff_matches_the_transport_timeline():
    # drop:0-1#2x2 with timeout:1e-3 -> two retries, 1 ms + 2 ms waited:
    # the exact numbers rust/tests/faults.rs pins on the sender's CommStats.
    assert abs(_retry_wait(2, RETRY_TIMEOUT) - 3e-3) < 1e-12
    assert _retry_wait(0, RETRY_TIMEOUT) == 0.0
    assert abs(_retry_wait(3, RETRY_TIMEOUT) - 7e-3) < 1e-12
    # Doubling: each extra drop of the same message costs more than all
    # previous waits combined, so stuck links surface fast in the stats.
    for k in range(1, 6):
        assert _retry_wait(k + 1, RETRY_TIMEOUT) > 2.0 * _retry_wait(
            k, RETRY_TIMEOUT
        ) - RETRY_TIMEOUT * 1e-9
