"""L2: the JAX tile-op library that the rust coordinator AOT-loads.

CUPLSS-RS stores every distributed matrix as fixed-size TILE x TILE local
tiles, so every accelerator call made from the rust request path is one of a
small, closed set of *fixed-shape* computations — exactly what AOT (static
shapes) requires.  This module defines that set:

  BLAS-3 hot spots (route through the L1 Pallas kernels, gemm.py / gemv.py):
    gemm          C = A @ B                         (SUMMA inner step)
    gemm_update   C -= A @ B                        (LU/Chol trailing update)
    gemv          y = A @ x                         (Krylov matvec shard)
    gemv_update   y -= A @ x
  Factor-tile ops (plain jax -> HLO Cholesky / TriangularSolve):
    potrf         L = chol(A)                       (diagonal tile)
    trsm_llu      solve L X = B, unit lower         (LU: U12 row)
    trsm_ru       solve X U = B                     (LU: L21 column)
    trsm_rlt      solve X L^T = B                   (Chol: L21 column)
    trsv_lu/l/u/lt triangular vector solves          (fwd/back substitution)
  BLAS-1 pair (kept for engine completeness / the GPU-offload cost story):
    dot, axpy

Each op carries its example shapes and an exact flop count so that the rust
cost models (accel/costmodel.rs) charge the virtual clock correctly.  The
AOT driver (aot.py) lowers every (op, dtype, tile) combination to HLO text.

This module is build-time only: nothing here is imported at solve time.
"""

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from compile.kernels import gemm as gemm_k
from compile.kernels import gemv as gemv_k
from compile.kernels import tri

jax.config.update("jax_enable_x64", True)

# Tile sizes the library ships artifacts for.  128 is the MXU-native block;
# 256 is the default library tile (2x2 MXU blocks per Pallas grid step).
TILES = (128, 256)
DTYPES = ("f32", "f64")

_NP_DTYPE = {"f32": jnp.float32, "f64": jnp.float64}


# --------------------------------------------------------------------------
# Op definitions.  Each entry:
#   name -> (builder, arg_shapes, flops_fn)
# where arg_shapes is a tuple of shape-lambdas over the tile size t, and
# flops_fn(t) is the exact floating-op count charged by the cost model.
# --------------------------------------------------------------------------


def _gemm(a, b):
    return (gemm_k.gemm(a, b),)


def _gemm_update(c, a, b):
    return (gemm_k.gemm_update(c, a, b),)


def _gemm_acc(c, a, b):
    # C += A @ B: the SUMMA accumulation fused into one kernel so the C
    # tile can stay device-resident across panel steps (rust DESIGN.md §12).
    return (gemm_k.gemm_acc(c, a, b),)


def _gemv(a, x):
    return (gemv_k.gemv(a, x),)


def _gemv_t(a, x):
    # y = A^T x  (BiCG's transpose matvec).  The Pallas GEMV kernel walks the
    # row-block grid of A^T; jnp transpose fuses into the same HLO module.
    return (gemv_k.gemv(a.T, x),)


def _gemm_nt_update(c, a, b):
    # C -= A @ B^T  (block-Cholesky trailing update: A(i,j) -= L(i,k) L(j,k)^T)
    return (gemm_k.gemm_update(c, a, b.T),)


def _gemv_update(y, a, x):
    return (gemv_k.gemv_update(y, a, x),)


def _gemv_acc(y, a, x):
    # y += A @ x: the matvec partial-sum accumulation fused into one kernel,
    # so pgemv's output block stays device-resident across a rank's tile-row
    # sweep (rust DESIGN.md §13).
    return (gemv_k.gemv_acc(y, a, x),)


def _gemv_t_acc(y, a, x):
    # y += A^T @ x (pgemv_t / BiCG's transpose sequence); the transpose
    # fuses into the same HLO module, as for gemv_t.
    return (gemv_k.gemv_acc(y, a.T, x),)


# Factor-tile ops come from kernels/tri.py: portable-HLO implementations
# (jax.scipy's solve_triangular / jnp.linalg.cholesky lower to LAPACK
# typed-FFI custom-calls on CPU, which xla_extension 0.5.1 cannot compile).


def _potrf(a):
    return (tri.potrf(a),)


def _trsm_llu(l, b):
    return (tri.trsm_llu(l, b),)


def _trsm_ru(b, u):
    return (tri.trsm_ru(b, u),)


def _trsm_rlt(b, l):
    return (tri.trsm_rlt(b, l),)


def _trsv_lu(l, b):
    return (tri.trsv_lu(l, b),)


def _trsv_l(l, b):
    return (tri.trsv_l(l, b),)


def _trsv_u(u, y):
    return (tri.trsv_u(u, y),)


def _trsv_lt(l, y):
    return (tri.trsv_lt(l, y),)


def _dot(x, y):
    return (jnp.dot(x, y, preferred_element_type=x.dtype),)


def _axpy(alpha, x, y):
    return (alpha * x + y,)


def _mm(t):
    return (t, t)


def _v(t):
    return (t,)


def _s(_t):
    return ()


OPS = {
    # name:        (builder,      arg shapes,         flops(t))
    "gemm":        (_gemm,        (_mm, _mm),         lambda t: 2 * t**3),
    "gemm_acc":    (_gemm_acc,    (_mm, _mm, _mm),    lambda t: 2 * t**3 + t * t),
    "gemm_update": (_gemm_update, (_mm, _mm, _mm),    lambda t: 2 * t**3 + t * t),
    "gemv":        (_gemv,        (_mm, _v),          lambda t: 2 * t * t),
    "gemv_t":      (_gemv_t,      (_mm, _v),          lambda t: 2 * t * t),
    "gemv_update": (_gemv_update, (_v, _mm, _v),      lambda t: 2 * t * t + t),
    "gemv_acc":    (_gemv_acc,    (_v, _mm, _v),      lambda t: 2 * t * t + t),
    "gemv_t_acc":  (_gemv_t_acc,  (_v, _mm, _v),      lambda t: 2 * t * t + t),
    "gemm_nt_update": (_gemm_nt_update, (_mm, _mm, _mm), lambda t: 2 * t**3 + t * t),
    "potrf":       (_potrf,       (_mm,),             lambda t: t**3 // 3),
    "trsm_llu":    (_trsm_llu,    (_mm, _mm),         lambda t: t**3),
    "trsm_ru":     (_trsm_ru,     (_mm, _mm),         lambda t: t**3),
    "trsm_rlt":    (_trsm_rlt,    (_mm, _mm),         lambda t: t**3),
    "trsv_lu":     (_trsv_lu,     (_mm, _v),          lambda t: t * t),
    "trsv_l":      (_trsv_l,      (_mm, _v),          lambda t: t * t),
    "trsv_u":      (_trsv_u,      (_mm, _v),          lambda t: t * t),
    "trsv_lt":     (_trsv_lt,     (_mm, _v),          lambda t: t * t),
    "dot":         (_dot,         (_v, _v),           lambda t: 2 * t),
    "axpy":        (_axpy,        (_s, _v, _v),       lambda t: 2 * t),
}


def example_args(name, tile, dtype):
    """ShapeDtypeStructs for lowering `name` at tile size `tile`."""
    _, shapes, _ = OPS[name]
    np_dt = _NP_DTYPE[dtype]
    return tuple(jax.ShapeDtypeStruct(s(tile), np_dt) for s in shapes)


def lower(name, tile, dtype):
    """jax.jit-lower one op to a Lowered object (static shapes)."""
    builder, _, _ = OPS[name]
    return jax.jit(builder).lower(*example_args(name, tile, dtype))


def artifact_name(name, tile, dtype):
    return f"{name}_{dtype}_{tile}"
