"""L1 Pallas kernel: tiled GEMV (and its accumulating variant).

The iterative solvers (CG/BiCG/BiCGSTAB/GMRES) are matvec-dominated; on each
rank the local shard of the distributed matvec is a dense (tile-rows x n_loc)
GEMV.  The kernel tiles the matrix into (bm, bk) VMEM blocks and walks the
row-block x col-block grid; the output row block stays resident in VMEM
across the K walk (its index map ignores k), exactly like the GEMM kernel.

The vector operand is blocked as (bk,) slices of x.  As with GEMM, the MXU
executes the (bm, bk) @ (bk,) contraction; interpret=True for the CPU PJRT
path (see gemm.py for the rationale).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = 128


def _gemv_kernel(a_ref, x_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=o_ref.dtype
    )


def _gemv_update_kernel(y_ref, a_ref, x_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = y_ref[...]

    o_ref[...] -= jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=o_ref.dtype
    )


def _gemv_acc_kernel(y_ref, a_ref, x_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = y_ref[...]

    o_ref[...] += jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=o_ref.dtype
    )


def _specs(m, k, bm, bk):
    if m % bm or k % bk:
        raise ValueError(f"gemv dims ({m},{k}) must be multiples of ({bm},{bk})")
    grid = (m // bm, k // bk)
    a_spec = pl.BlockSpec((bm, bk), lambda i, kk: (i, kk))
    x_spec = pl.BlockSpec((bk,), lambda i, kk: (kk,))
    o_spec = pl.BlockSpec((bm,), lambda i, kk: (i,))
    return grid, a_spec, x_spec, o_spec


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def gemv(a, x, bm=DEFAULT_BLOCK, bk=DEFAULT_BLOCK):
    """y = A @ x via the Pallas tiled kernel.  a: (m, k), x: (k,)."""
    m, ka = a.shape
    assert ka == x.shape[0], (a.shape, x.shape)
    grid, a_spec, x_spec, o_spec = _specs(m, ka, bm, bk)
    return pl.pallas_call(
        _gemv_kernel,
        grid=grid,
        in_specs=[a_spec, x_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, x)


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def gemv_update(y, a, x, bm=DEFAULT_BLOCK, bk=DEFAULT_BLOCK):
    """y_out = y - A @ x via the Pallas tiled kernel (matvec accumulation)."""
    m, ka = a.shape
    assert ka == x.shape[0] and y.shape[0] == m, (y.shape, a.shape, x.shape)
    grid, a_spec, x_spec, o_spec = _specs(m, ka, bm, bk)
    y_spec = pl.BlockSpec((bm,), lambda i, kk: (i,))
    return pl.pallas_call(
        _gemv_update_kernel,
        grid=grid,
        in_specs=[y_spec, a_spec, x_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m,), y.dtype),
        interpret=True,
    )(y, a, x)


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def gemv_acc(y, a, x, bm=DEFAULT_BLOCK, bk=DEFAULT_BLOCK):
    """y_out = y + A @ x as one fused Pallas kernel.

    The matvec partial-sum accumulation of the distributed pgemv: fusing the
    add lets the output block stay device-resident across a rank's tile-row
    sweep instead of round-tripping through a host axpy per tile (rust
    DESIGN.md §13).
    """
    m, ka = a.shape
    assert ka == x.shape[0] and y.shape[0] == m, (y.shape, a.shape, x.shape)
    grid, a_spec, x_spec, o_spec = _specs(m, ka, bm, bk)
    y_spec = pl.BlockSpec((bm,), lambda i, kk: (i,))
    return pl.pallas_call(
        _gemv_acc_kernel,
        grid=grid,
        in_specs=[y_spec, a_spec, x_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m,), y.dtype),
        interpret=True,
    )(y, a, x)
