"""Pure-jnp reference oracles for every L1/L2 tile operation.

These are the single source of numerical truth: the Pallas kernels
(``gemm.py``, ``gemv.py``) and the L2 tile ops (``model.py``) are tested
against these functions by ``python/tests/``.  They intentionally use only
plain ``jax.numpy`` / ``jax.scipy`` calls — no Pallas, no custom lowering —
so a disagreement always indicts the kernel, not the oracle.
"""

import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular


def ref_gemm(a, b):
    """C = A @ B."""
    return jnp.dot(a, b, preferred_element_type=a.dtype)


def ref_gemm_update(c, a, b):
    """Delayed rank-k update: C_out = C - A @ B (the BLAS-3 core of block LU)."""
    return c - jnp.dot(a, b, preferred_element_type=a.dtype)


def ref_gemm_acc(c, a, b):
    """SUMMA accumulation: C_out = C + A @ B (the fused gemm-plus-axpy)."""
    return c + jnp.dot(a, b, preferred_element_type=a.dtype)


def ref_syrk_update(c, a):
    """Symmetric update: C_out = C - A @ A^T (the BLAS-3 core of block Cholesky)."""
    return c - jnp.dot(a, a.T, preferred_element_type=a.dtype)


def ref_gemv(a, x):
    """y = A @ x."""
    return jnp.dot(a, x, preferred_element_type=a.dtype)


def ref_gemv_update(y, a, x):
    """y_out = y - A @ x (distributed matvec accumulation step)."""
    return y - jnp.dot(a, x, preferred_element_type=a.dtype)


def ref_gemv_acc(y, a, x):
    """y_out = y + A @ x (device-resident matvec partial accumulation)."""
    return y + jnp.dot(a, x, preferred_element_type=a.dtype)


def ref_gemv_t_acc(y, a, x):
    """y_out = y + A^T @ x (transpose twin, BiCG's second sequence)."""
    return y + jnp.dot(a.T, x, preferred_element_type=a.dtype)


def ref_trsm_llu(l, b):
    """Solve L X = B with L unit lower triangular (LU panel: U12 block row)."""
    return solve_triangular(l, b, lower=True, unit_diagonal=True)


def ref_trsm_ru(b, u):
    """Solve X U = B with U upper triangular (LU panel: L21 block column).

    X U = B  <=>  U^T X^T = B^T.
    """
    return solve_triangular(u.T, b.T, lower=True).T


def ref_trsm_rlt(b, l):
    """Solve X L^T = B with L lower triangular (Cholesky panel: L21 block).

    X L^T = B  <=>  L X^T = B^T.
    """
    return solve_triangular(l, b.T, lower=True).T


def ref_trsv_lu(l, b):
    """Solve L y = b, L unit lower (forward substitution after LU)."""
    return solve_triangular(l, b, lower=True, unit_diagonal=True)


def ref_trsv_l(l, b):
    """Solve L y = b, L general lower (forward substitution after Cholesky)."""
    return solve_triangular(l, b, lower=True)


def ref_trsv_u(u, y):
    """Solve U x = y, U upper (backward substitution)."""
    return solve_triangular(u, y, lower=False)


def ref_trsv_lt(l, y):
    """Solve L^T x = y with L lower (Cholesky backward substitution)."""
    return solve_triangular(l.T, y, lower=False)


def ref_potrf(a):
    """Lower Cholesky factor of an SPD tile."""
    return jnp.linalg.cholesky(a)


def ref_dot(x, y):
    """Inner product (returned as a rank-0 array)."""
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def ref_axpy(alpha, x, y):
    """y_out = alpha * x + y (alpha is a rank-0 array)."""
    return alpha * x + y
