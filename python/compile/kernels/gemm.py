"""L1 Pallas kernel: tiled GEMM and the delayed rank-k update.

This is the compute hot-spot of the whole library — the paper offloads
exactly this operation (the trailing-submatrix update of block LU and the
big multiplies of the Krylov solvers) to CUBLAS.  Here it is re-thought for
the TPU memory system instead of mechanically ported from CUDA:

* CUDA threadblock tiling over shared memory  ->  ``BlockSpec`` tiling over
  VMEM.  The grid walks (M/bm, N/bn, K/bk); at each step Pallas streams an
  (bm, bk) A-tile and a (bk, bn) B-tile HBM->VMEM, and the kernel accumulates
  into the (bm, bn) output block, which stays resident in VMEM across the
  whole K walk (its index map ignores ``k``).
* SIMT FMA loops  ->  a single ``jnp.dot`` per grid step so the MXU systolic
  array executes the inner product; ``preferred_element_type`` pins f32 (or
  f64) accumulation.
* Block shapes default to 128 — the MXU native tile — and must divide the
  operand shapes (the tile library pads everything to multiples of 128).

VMEM footprint per grid step (f32, bm=bn=bk=128):
    A-tile 64 KiB + B-tile 64 KiB + C-block 64 KiB = 192 KiB  << 16 MiB,
leaving room for double-buffering of the A/B streams by the compiler.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness (pytest vs ``ref.py``) plus AOT lowering are
the only things the build path needs.  Real-TPU efficiency is estimated in
DESIGN.md / EXPERIMENTS.md from the BlockSpec instead.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = 128


def _matmul_kernel(a_ref, b_ref, o_ref, *, k_steps):
    """One (i, j, k) grid step: o[i,j] += a[i,k] @ b[k,j].

    The output block's index map ignores k, so ``o_ref`` is the same VMEM
    block for the whole K walk: initialise it at k == 0, accumulate after.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def _update_kernel(c_ref, a_ref, b_ref, o_ref, *, k_steps):
    """One grid step of the delayed update: o[i,j] = c[i,j] - sum_k a[i,k]@b[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] -= jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def _acc_kernel(c_ref, a_ref, b_ref, o_ref, *, k_steps):
    """One grid step of the accumulation: o[i,j] = c[i,j] + sum_k a[i,k]@b[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def _grid_specs(m, n, k, bm, bn, bk):
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"matmul dims ({m},{n},{k}) must be multiples of blocks ({bm},{bn},{bk})"
        )
    grid = (m // bm, n // bn, k // bk)
    a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    return grid, a_spec, b_spec, o_spec


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(a, b, bm=DEFAULT_BLOCK, bn=DEFAULT_BLOCK, bk=DEFAULT_BLOCK):
    """C = A @ B via the Pallas tiled kernel.

    a: (m, k), b: (k, n) with dims multiples of the block shape.
    """
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb, (a.shape, b.shape)
    grid, a_spec, b_spec, o_spec = _grid_specs(m, n, ka, bm, bn, bk)
    kernel = functools.partial(_matmul_kernel, k_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[a_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_update(c, a, b, bm=DEFAULT_BLOCK, bn=DEFAULT_BLOCK, bk=DEFAULT_BLOCK):
    """Delayed rank-k update C_out = C - A @ B via the Pallas tiled kernel.

    This single fused kernel is the block-LU/Cholesky trailing update — the
    operation the paper converts from k rank-1 updates into one rank-k
    (BLAS-3) update, and the one it sends to the GPU.
    """
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb and c.shape == (m, n), (c.shape, a.shape, b.shape)
    grid, a_spec, b_spec, o_spec = _grid_specs(m, n, ka, bm, bn, bk)
    c_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    kernel = functools.partial(_update_kernel, k_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[c_spec, a_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(c, a, b)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_acc(c, a, b, bm=DEFAULT_BLOCK, bn=DEFAULT_BLOCK, bk=DEFAULT_BLOCK):
    """SUMMA accumulation C_out = C + A @ B as one fused Pallas kernel.

    The residency refactor folds the coordinator's former gemm-then-axpy
    pair into this single kernel so the C tile can stay device-resident
    across panel steps (DESIGN.md §12).
    """
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb and c.shape == (m, n), (c.shape, a.shape, b.shape)
    grid, a_spec, b_spec, o_spec = _grid_specs(m, n, ka, bm, bn, bk)
    c_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    kernel = functools.partial(_acc_kernel, k_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[c_spec, a_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(c, a, b)

