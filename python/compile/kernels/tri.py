"""Triangular solves and Cholesky as *portable HLO* (no LAPACK custom-calls).

On CPU, jax lowers ``jax.scipy.linalg.solve_triangular`` and
``jnp.linalg.cholesky`` to LAPACK typed-FFI custom-calls, which the
``xla`` crate's xla_extension 0.5.1 refuses to compile
("Unknown custom-call API version ... API_VERSION_TYPED_FFI").  The AOT
artifacts therefore need these factor-tile ops expressed in primitive HLO:
``lax.fori_loop`` + masked dense contractions, which lower to While + dot.

Each step does a full masked row/column contraction (O(t) flops per element
instead of the triangular half), trading ~2x arithmetic inside a t x t tile
for portability — the virtual-time cost models charge the *algorithmic* flop
count, and these ops are O(t^2)/O(t^3) next to the O(t^3) GEMM stream, so the
overhead is invisible at solver scale.

Correctness is pinned to the jax.scipy/jnp oracles by python/tests.
"""

import jax
import jax.numpy as jnp
from jax import lax


def trsm_llu(l, b):
    """Solve L X = B with L unit lower triangular; B is (t, m)."""
    t = l.shape[0]
    idx = jnp.arange(t)

    def body(i, x):
        row = l[i, :] * (idx < i)  # L[i, :i], masked
        xi = b[i, :] - row @ x
        return x.at[i, :].set(xi)

    return lax.fori_loop(0, t, body, jnp.zeros_like(b))


def trsv_lu(l, b):
    """Solve L y = b with L unit lower triangular; b is (t,)."""
    t = l.shape[0]
    idx = jnp.arange(t)

    def body(i, y):
        row = l[i, :] * (idx < i)
        return y.at[i].set(b[i] - row @ y)

    return lax.fori_loop(0, t, body, jnp.zeros_like(b))


def trsv_l(l, b):
    """Solve L y = b with L general lower triangular."""
    t = l.shape[0]
    idx = jnp.arange(t)

    def body(i, y):
        row = l[i, :] * (idx < i)
        return y.at[i].set((b[i] - row @ y) / l[i, i])

    return lax.fori_loop(0, t, body, jnp.zeros_like(b))


def trsv_u(u, b):
    """Solve U x = b with U upper triangular (backward substitution)."""
    t = u.shape[0]
    idx = jnp.arange(t)

    def body(k, x):
        i = t - 1 - k
        row = u[i, :] * (idx > i)
        return x.at[i].set((b[i] - row @ x) / u[i, i])

    return lax.fori_loop(0, t, body, jnp.zeros_like(b))


def trsv_lt(l, b):
    """Solve L^T x = b with L lower triangular ((L^T)[i,j] = L[j,i])."""
    t = l.shape[0]
    idx = jnp.arange(t)

    def body(k, x):
        i = t - 1 - k
        col = l[:, i] * (idx > i)  # row i of L^T beyond the diagonal
        return x.at[i].set((b[i] - col @ x) / l[i, i])

    return lax.fori_loop(0, t, body, jnp.zeros_like(b))


def trsm_ru(b, u):
    """Solve X U = B with U upper triangular; B is (m, t)."""
    t = u.shape[0]
    idx = jnp.arange(t)

    def body(j, x):
        col = u[:, j] * (idx < j)  # U[:j, j], masked
        xj = (b[:, j] - x @ col) / u[j, j]
        return x.at[:, j].set(xj)

    return lax.fori_loop(0, t, body, jnp.zeros_like(b))


def trsm_rlt(b, l):
    """Solve X L^T = B with L lower triangular; B is (m, t).

    Column j of the equation: X[:, :j] @ L[j, :j] + X[:, j] L[j, j] = B[:, j].
    """
    t = l.shape[0]
    idx = jnp.arange(t)

    def body(j, x):
        row = l[j, :] * (idx < j)  # L[j, :j], masked
        xj = (b[:, j] - x @ row) / l[j, j]
        return x.at[:, j].set(xj)

    return lax.fori_loop(0, t, body, jnp.zeros_like(b))


def potrf(a):
    """Lower Cholesky factor of an SPD tile, unblocked right-looking."""
    t = a.shape[0]
    idx = jnp.arange(t)

    def body(j, l):
        rowj = l[j, :] * (idx < j)  # L[j, :j]
        d = l[j, j] - rowj @ rowj
        ljj = jnp.sqrt(d)
        # Column j below the diagonal: (a[i,j] - L[i,:j].L[j,:j]) / ljj.
        contrib = l @ rowj
        col = (l[:, j] - contrib) / ljj
        new_col = jnp.where(idx == j, ljj, jnp.where(idx > j, col, 0.0))
        return l.at[:, j].set(new_col)

    return lax.fori_loop(0, t, body, a)
