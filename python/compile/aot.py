"""AOT driver: lower every (op, dtype, tile) combination to HLO text.

This is the only place python touches the build: ``make artifacts`` runs
``python -m compile.aot --out ../artifacts`` once, and the rust runtime
(rust/src/runtime) loads + PJRT-compiles the text files at startup.  Python
never runs on the solve path.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Alongside the .hlo.txt files we emit ``manifest.txt`` — a dependency-free
line format the rust side parses by hand (no serde in the offline crate
set)::

    <artifact> <op> <dtype> <tile> <flops> <arity> <in0,in1,...> <out>

shapes are 'x'-separated dims, 's' for scalar (rank-0).
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_str(shape) -> str:
    if len(shape) == 0:
        return "s"
    return "x".join(str(d) for d in shape)


def build_all(out_dir: str, tiles=None, dtypes=None, verbose=True) -> int:
    tiles = tiles or model.TILES
    dtypes = dtypes or model.DTYPES
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    count = 0
    for name, (_builder, shapes, flops_fn) in model.OPS.items():
        for dtype in dtypes:
            for tile in tiles:
                art = model.artifact_name(name, tile, dtype)
                path = os.path.join(out_dir, art + ".hlo.txt")
                lowered = model.lower(name, tile, dtype)
                text = to_hlo_text(lowered)
                with open(path, "w") as f:
                    f.write(text)
                in_shapes = ",".join(_shape_str(s(tile)) for s in shapes)
                out_shape = _shape_str(
                    lowered.out_info[0].shape
                    if isinstance(lowered.out_info, (list, tuple))
                    else lowered.out_info.shape
                )
                manifest_lines.append(
                    f"{art} {name} {dtype} {tile} {flops_fn(tile)} "
                    f"{len(shapes)} {in_shapes} {out_shape}"
                )
                count += 1
                if verbose:
                    print(f"  [{count}] {art}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    if verbose:
        print(f"wrote {count} artifacts + manifest.txt to {out_dir}")
    return count


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument("--tiles", default=None, help="comma list, e.g. 128,256")
    parser.add_argument("--dtypes", default=None, help="comma list, e.g. f32,f64")
    args = parser.parse_args()
    tiles = tuple(int(t) for t in args.tiles.split(",")) if args.tiles else None
    dtypes = tuple(args.dtypes.split(",")) if args.dtypes else None
    n = build_all(args.out, tiles=tiles, dtypes=dtypes)
    if n == 0:
        sys.exit("no artifacts produced")


if __name__ == "__main__":
    main()
