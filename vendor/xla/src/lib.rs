//! API-compatible stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The container this crate builds in has no PJRT plugin, so the accelerated
//! engine is *gated*, not linked: every type and signature
//! `cuplss::runtime::executor` touches exists here with the same shape, but
//! `compile`/`execute` return a descriptive [`Error`] instead of running HLO.
//! Because the accelerated paths all check for `artifacts/manifest.txt` first
//! (and fall back to the CPU engine), the stub never executes in tests — it
//! only has to type-check and fail loudly if someone forces the XLA arm
//! without the real bindings.
//!
//! Swapping in the real crate is a `Cargo.toml` change only.

use std::fmt;
use std::path::Path;

/// Error surfaced by the stub (and by the real bindings' fallible calls).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: PJRT is unavailable in this build (vendored xla stub); \
             install the real xla-rs bindings to run the accelerated engine"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub-local result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// XLA element dtypes (the two CUPLSS-RS uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit float.
    F32,
    /// 64-bit float.
    F64,
}

/// Types with an XLA dtype tag.
pub trait ArrayElement {
    /// The XLA element type of `Self`.
    const TY: ElementType;
}

/// Types whose memory layout XLA can consume directly.
pub trait NativeType: Copy + 'static {}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
}

impl ArrayElement for f64 {
    const TY: ElementType = ElementType::F64;
}

impl NativeType for f32 {}
impl NativeType for f64 {}

/// A host-side literal (shape + raw bytes).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    /// Build a literal from a dtype, a shape and raw bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = shape.iter().product();
        let bytes = match ty {
            ElementType::F32 => 4,
            ElementType::F64 => 8,
        };
        if data.len() != elems * bytes {
            return Err(Error(format!(
                "literal data is {} bytes but shape {shape:?} needs {}",
                data.len(),
                elems * bytes
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), data: data.to_vec() })
    }

    /// The element type.
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    /// The shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Unwrap a 1-tuple result (AOT modules lower with `return_tuple=True`).
    pub fn to_tuple1(&self) -> Result<Literal> {
        Ok(self.clone())
    }

    /// Copy out as a typed vector.
    pub fn to_vec<S: NativeType>(&self) -> Result<Vec<S>> {
        let size = std::mem::size_of::<S>();
        if self.data.len() % size != 0 {
            return Err(Error("literal bytes not a multiple of element size".into()));
        }
        let n = self.data.len() / size;
        let mut out = Vec::with_capacity(n);
        // SAFETY: NativeType is only implemented for plain-old-data floats;
        // the length check above keeps every read in bounds, and
        // read_unaligned tolerates the byte buffer's alignment.
        unsafe {
            let base = self.data.as_ptr();
            for i in 0..n {
                out.push(std::ptr::read_unaligned(base.add(i * size) as *const S));
            }
        }
        Ok(out)
    }
}

/// A device buffer handle (never materialised by the stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A PJRT client.  The stub constructs (so `Runtime::new` can report the
/// *artifact* situation first) but refuses to compile.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// The CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    /// Compile a computation to a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals; returns per-device,
    /// per-output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO-text artifact file.  The stub verifies the file is
    /// readable (so missing-artifact errors stay accurate) but does not
    /// parse the module.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        std::fs::read_to_string(path)
            .map_err(|e| Error(format!("cannot read HLO text {}: {e}", path.display())))?;
        Ok(HloModuleProto)
    }
}

/// An XLA computation.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a module proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let xs = [1.0f64, 2.0, 3.0];
        let bytes = unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, 24) };
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F64, &[3], bytes).unwrap();
        assert_eq!(lit.to_vec::<f64>().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(lit.shape(), &[3]);
    }

    #[test]
    fn literal_rejects_bad_len() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[4], &[0u8; 3])
                .is_err()
        );
    }

    #[test]
    fn stub_refuses_execution() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.compile(&XlaComputation::from_proto(&HloModuleProto)).is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
    }

    #[test]
    fn dtype_tags() {
        assert_eq!(<f32 as ArrayElement>::TY, ElementType::F32);
        assert_eq!(<f64 as ArrayElement>::TY, ElementType::F64);
    }
}
