//! Offline stand-in for the `num-traits` crate, restricted to what CUPLSS-RS
//! uses: the [`Float`] / [`NumAssign`] / [`FromPrimitive`] / [`ToPrimitive`]
//! bounds of `cuplss::Scalar`, implemented for `f32` and `f64` only.
//!
//! The trait *names and method signatures* match the real crate, so swapping
//! this path dependency for the crates.io `num-traits` is a one-line
//! `Cargo.toml` change with no source edits.

use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Rem, RemAssign, Sub, SubAssign};

/// Additive identity.
pub trait Zero: Sized + Add<Self, Output = Self> {
    /// The value `0`.
    fn zero() -> Self;
    /// Is this exactly `0`?
    fn is_zero(&self) -> bool;
}

/// Multiplicative identity.
pub trait One: Sized + Mul<Self, Output = Self> {
    /// The value `1`.
    fn one() -> Self;
}

/// The four arithmetic operators plus remainder (the real crate's `NumOps`).
pub trait NumOps<Rhs = Self, Output = Self>:
    Add<Rhs, Output = Output>
    + Sub<Rhs, Output = Output>
    + Mul<Rhs, Output = Output>
    + Div<Rhs, Output = Output>
    + Rem<Rhs, Output = Output>
{
}

impl<T, Rhs, Output> NumOps<Rhs, Output> for T where
    T: Add<Rhs, Output = Output>
        + Sub<Rhs, Output = Output>
        + Mul<Rhs, Output = Output>
        + Div<Rhs, Output = Output>
        + Rem<Rhs, Output = Output>
{
}

/// Basic numeric type: identities, equality and the arithmetic operators.
pub trait Num: PartialEq + Zero + One + NumOps {}

impl<T: PartialEq + Zero + One + NumOps> Num for T {}

/// The compound-assignment operators (the real crate's `NumAssignOps`).
pub trait NumAssignOps<Rhs = Self>:
    AddAssign<Rhs> + SubAssign<Rhs> + MulAssign<Rhs> + DivAssign<Rhs> + RemAssign<Rhs>
{
}

impl<T, Rhs> NumAssignOps<Rhs> for T where
    T: AddAssign<Rhs> + SubAssign<Rhs> + MulAssign<Rhs> + DivAssign<Rhs> + RemAssign<Rhs>
{
}

/// `Num` with compound assignment.
pub trait NumAssign: Num + NumAssignOps {}

impl<T: Num + NumAssignOps> NumAssign for T {}

/// Conversion out of a numeric type (lossy where necessary).
pub trait ToPrimitive {
    /// To `i64`, `None` when out of range.
    fn to_i64(&self) -> Option<i64>;
    /// To `u64`, `None` when negative or out of range.
    fn to_u64(&self) -> Option<u64>;
    /// To `usize`.
    fn to_usize(&self) -> Option<usize> {
        self.to_u64().map(|v| v as usize)
    }
    /// To `f32` (always succeeds for floats, with rounding).
    fn to_f32(&self) -> Option<f32>;
    /// To `f64`.
    fn to_f64(&self) -> Option<f64>;
}

/// Conversion into a numeric type.
pub trait FromPrimitive: Sized {
    /// From `i64`.
    fn from_i64(n: i64) -> Option<Self>;
    /// From `u64`.
    fn from_u64(n: u64) -> Option<Self>;
    /// From `usize`.
    fn from_usize(n: usize) -> Option<Self> {
        Self::from_u64(n as u64)
    }
    /// From `f32`.
    fn from_f32(n: f32) -> Option<Self> {
        Self::from_f64(n as f64)
    }
    /// From `f64`.
    fn from_f64(n: f64) -> Option<Self>;
}

/// IEEE-754 floating point operations (the subset CUPLSS-RS calls).
pub trait Float: Num + Copy + PartialOrd + Neg<Output = Self> {
    /// Not-a-number.
    fn nan() -> Self;
    /// Positive infinity.
    fn infinity() -> Self;
    /// Negative infinity.
    fn neg_infinity() -> Self;
    /// Smallest positive normal value.
    fn min_positive_value() -> Self;
    /// Machine epsilon (distance from 1.0 to the next representable value).
    fn epsilon() -> Self;
    /// Largest finite value.
    fn max_value() -> Self;
    /// Smallest finite value.
    fn min_value() -> Self;
    /// Absolute value.
    fn abs(self) -> Self;
    /// Square root.
    fn sqrt(self) -> Self;
    /// Natural logarithm.
    fn ln(self) -> Self;
    /// Exponential.
    fn exp(self) -> Self;
    /// Integer power.
    fn powi(self, n: i32) -> Self;
    /// Float power.
    fn powf(self, p: Self) -> Self;
    /// Sine.
    fn sin(self) -> Self;
    /// Cosine.
    fn cos(self) -> Self;
    /// Round down.
    fn floor(self) -> Self;
    /// Round up.
    fn ceil(self) -> Self;
    /// Round to nearest.
    fn round(self) -> Self;
    /// Truncate toward zero.
    fn trunc(self) -> Self;
    /// Reciprocal.
    fn recip(self) -> Self;
    /// Sign (`±1`, or NaN).
    fn signum(self) -> Self;
    /// Elementwise maximum (NaN-ignoring, like `f64::max`).
    fn max(self, other: Self) -> Self;
    /// Elementwise minimum.
    fn min(self, other: Self) -> Self;
    /// `sqrt(self² + other²)` without intermediate overflow.
    fn hypot(self, other: Self) -> Self;
    /// Is this NaN?
    fn is_nan(self) -> bool;
    /// Is this finite?
    fn is_finite(self) -> bool;
    /// Is this ±infinity?
    fn is_infinite(self) -> bool;
    /// Is the sign bit clear?
    fn is_sign_positive(self) -> bool;
    /// Is the sign bit set?
    fn is_sign_negative(self) -> bool;
}

macro_rules! impl_float {
    ($t:ty) => {
        impl Zero for $t {
            fn zero() -> Self {
                0.0
            }
            fn is_zero(&self) -> bool {
                *self == 0.0
            }
        }

        impl One for $t {
            fn one() -> Self {
                1.0
            }
        }

        impl ToPrimitive for $t {
            fn to_i64(&self) -> Option<i64> {
                if self.is_finite() && *self >= i64::MIN as $t && *self <= i64::MAX as $t {
                    Some(*self as i64)
                } else {
                    None
                }
            }
            fn to_u64(&self) -> Option<u64> {
                if self.is_finite() && *self >= 0.0 && *self <= u64::MAX as $t {
                    Some(*self as u64)
                } else {
                    None
                }
            }
            fn to_f32(&self) -> Option<f32> {
                Some(*self as f32)
            }
            fn to_f64(&self) -> Option<f64> {
                Some(*self as f64)
            }
        }

        impl FromPrimitive for $t {
            fn from_i64(n: i64) -> Option<Self> {
                Some(n as $t)
            }
            fn from_u64(n: u64) -> Option<Self> {
                Some(n as $t)
            }
            fn from_f64(n: f64) -> Option<Self> {
                Some(n as $t)
            }
        }

        impl Float for $t {
            fn nan() -> Self {
                <$t>::NAN
            }
            fn infinity() -> Self {
                <$t>::INFINITY
            }
            fn neg_infinity() -> Self {
                <$t>::NEG_INFINITY
            }
            fn min_positive_value() -> Self {
                <$t>::MIN_POSITIVE
            }
            fn epsilon() -> Self {
                <$t>::EPSILON
            }
            fn max_value() -> Self {
                <$t>::MAX
            }
            fn min_value() -> Self {
                <$t>::MIN
            }
            fn abs(self) -> Self {
                <$t>::abs(self)
            }
            fn sqrt(self) -> Self {
                <$t>::sqrt(self)
            }
            fn ln(self) -> Self {
                <$t>::ln(self)
            }
            fn exp(self) -> Self {
                <$t>::exp(self)
            }
            fn powi(self, n: i32) -> Self {
                <$t>::powi(self, n)
            }
            fn powf(self, p: Self) -> Self {
                <$t>::powf(self, p)
            }
            fn sin(self) -> Self {
                <$t>::sin(self)
            }
            fn cos(self) -> Self {
                <$t>::cos(self)
            }
            fn floor(self) -> Self {
                <$t>::floor(self)
            }
            fn ceil(self) -> Self {
                <$t>::ceil(self)
            }
            fn round(self) -> Self {
                <$t>::round(self)
            }
            fn trunc(self) -> Self {
                <$t>::trunc(self)
            }
            fn recip(self) -> Self {
                <$t>::recip(self)
            }
            fn signum(self) -> Self {
                <$t>::signum(self)
            }
            fn max(self, other: Self) -> Self {
                <$t>::max(self, other)
            }
            fn min(self, other: Self) -> Self {
                <$t>::min(self, other)
            }
            fn hypot(self, other: Self) -> Self {
                <$t>::hypot(self, other)
            }
            fn is_nan(self) -> bool {
                <$t>::is_nan(self)
            }
            fn is_finite(self) -> bool {
                <$t>::is_finite(self)
            }
            fn is_infinite(self) -> bool {
                <$t>::is_infinite(self)
            }
            fn is_sign_positive(self) -> bool {
                <$t>::is_sign_positive(self)
            }
            fn is_sign_negative(self) -> bool {
                <$t>::is_sign_negative(self)
            }
        }
    };
}

impl_float!(f32);
impl_float!(f64);

#[cfg(test)]
mod tests {
    use super::*;

    fn generic_sum<S: Float + FromPrimitive + ToPrimitive>(xs: &[S]) -> f64 {
        let mut acc = S::zero();
        for &x in xs {
            acc = acc + x;
        }
        acc.to_f64().unwrap()
    }

    #[test]
    fn float_bounds_compose() {
        assert_eq!(generic_sum(&[1.0f64, 2.0, 3.0]), 6.0);
        assert_eq!(generic_sum(&[1.0f32, 2.0]), 3.0);
    }

    #[test]
    fn identities_and_eps() {
        assert_eq!(f64::zero(), 0.0);
        assert_eq!(f32::one(), 1.0);
        assert!(f64::epsilon() > 0.0 && f64::epsilon() < 1e-10);
        assert!(f32::epsilon() > f64::epsilon() as f32);
    }

    #[test]
    fn conversions() {
        assert_eq!(f64::from_usize(7).unwrap(), 7.0);
        assert_eq!(3.9f64.to_i64().unwrap(), 3);
        assert_eq!((-1.0f64).to_u64(), None);
        assert_eq!(f64::nan().to_i64(), None);
    }

    #[test]
    fn float_methods_delegate() {
        assert_eq!(Float::abs(-2.0f64), 2.0);
        assert_eq!(Float::sqrt(9.0f32), 3.0);
        assert_eq!(Float::max(1.0f64, 2.0), 2.0);
        assert!(Float::is_nan(f64::nan()));
    }
}
